//! Serving-runtime concurrency tests: differential bit-exactness under
//! bursty multi-client load across worker counts and backends, graceful
//! shutdown with requests in flight (watchdog-guarded), and the
//! feature-length error contract shared by every submission path.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use neuralut::fabric::{FabricOptions, Model};
use neuralut::luts::{random_network, LutNetwork};
use neuralut::netlist::Simulator;
use neuralut::server::{Server, ServerError};

/// Compile-and-serve through the unified fabric API — the only way a
/// server starts.
fn serve(net: &Arc<LutNetwork>, opts: &FabricOptions) -> Server {
    Model::from_arc(net.clone()).compile(opts).unwrap().serve()
}

/// Deterministic per-(thread, request) feature vector.
fn feats_for(thread: usize, i: usize, n_feat: usize) -> Vec<f32> {
    (0..n_feat)
        .map(|j| ((thread * 31 + i * 7 + j) % 17) as f32 / 17.0)
        .collect()
}

/// Run `f` on a helper thread and panic if it does not finish in time —
/// turns a deadlock into a test failure instead of a hung `cargo test`.
/// A panic inside `f` is re-raised as itself, not mislabeled as a deadlock.
fn with_watchdog<F: FnOnce() + Send + 'static>(label: &str, timeout: Duration, f: F) {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(timeout) {
        Ok(()) => {
            handle.join().unwrap();
        }
        // Sender dropped without sending: the closure panicked — propagate
        // the original panic payload.
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(panic) = handle.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("{label}: deadlocked (watchdog fired after {timeout:?})");
        }
    }
}

#[test]
fn concurrent_bursty_clients_are_bit_exact_across_workers_and_backends() {
    let net = Arc::new(random_network(71, 8, 2, &[6, 3], 3, 2, 4));
    // Burst sizes deliberately straddle the bitslice engine's 64-lane
    // word: 63 and 65 force ragged tail blocks inside served batches.
    let bursts = [1usize, 63, 65, 7];
    for workers in [1usize, 2, 8] {
        for backend in ["scalar", "bitsliced"] {
            let server = serve(
                &net,
                &FabricOptions::new()
                    .workers(workers)
                    .max_batch(32)
                    .batch_window(Duration::from_micros(200))
                    .backend(backend),
            );
            let client = server.client();
            std::thread::scope(|scope| {
                for t in 0..4usize {
                    let c = client.clone();
                    let net = net.clone();
                    scope.spawn(move || {
                        let sim = Simulator::new(&net);
                        for (b, &size) in bursts.iter().enumerate() {
                            // Burst: submit all async, then collect — the
                            // servers sees overlapping multi-client load.
                            let mut pending = Vec::with_capacity(size);
                            let mut want = Vec::with_capacity(size);
                            for i in 0..size {
                                let f = feats_for(t, b * 1000 + i, 8);
                                want.push(sim.simulate_batch(&f).predictions[0]);
                                pending.push(c.infer_async(f).unwrap());
                            }
                            for (rx, want) in pending.into_iter().zip(want) {
                                let got = rx.recv().unwrap();
                                assert_eq!(
                                    got.prediction, want,
                                    "diverged: workers={workers} backend={backend}"
                                );
                                assert!(got.worker < workers);
                            }
                        }
                    });
                }
            });
            let total: usize = bursts.iter().sum::<usize>() * 4;
            let s = server.stats();
            assert_eq!(
                s.served, total as u64,
                "stats lost requests: workers={workers} backend={backend}"
            );
            assert_eq!(s.per_worker_served.iter().sum::<u64>(), total as u64);
        }
    }
}

#[test]
fn dropping_server_with_requests_in_flight_answers_them_all() {
    with_watchdog("shutdown-drain", Duration::from_secs(120), || {
        for backend in ["scalar", "bitsliced"] {
            let net = Arc::new(random_network(72, 6, 2, &[4, 2], 2, 2, 4));
            let server = serve(
                &net,
                &FabricOptions::new()
                    .workers(2)
                    .max_batch(4)
                    .batch_window(Duration::from_micros(500))
                    .backend(backend),
            );
            let client = server.client();
            let mut pending = Vec::new();
            for i in 0..300usize {
                let f: Vec<f32> = (0..6).map(|j| ((i + j) % 9) as f32 / 9.0).collect();
                pending.push(client.infer_async(f).unwrap());
            }
            // Drop with (almost certainly) requests still queued: shutdown
            // must drain — every accepted request gets an answer.
            drop(server);
            for rx in pending {
                rx.recv().expect("accepted request dropped at shutdown");
            }
            // And new submissions fail fast with the explicit error.
            let err = client.infer(vec![0.0; 6]).unwrap_err();
            assert_eq!(err.downcast_ref::<ServerError>(), Some(&ServerError::Stopped));
        }
    });
}

#[test]
fn shutdown_races_with_live_clients_without_deadlock() {
    with_watchdog("shutdown-race", Duration::from_secs(120), || {
        let net = Arc::new(random_network(73, 6, 2, &[4, 2], 2, 2, 4));
        let server = serve(
            &net,
            &FabricOptions::new()
                .workers(2)
                .max_batch(8)
                .batch_window(Duration::from_micros(100)),
        );
        let client = server.client();
        let clients: Vec<_> = (0..4usize)
            .map(|t| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let mut answered = 0usize;
                    for i in 0.. {
                        let f = feats_for(t, i, 6);
                        match c.infer(f) {
                            Ok(_) => answered += 1,
                            Err(e) => {
                                // The only acceptable refusal is Stopped.
                                assert_eq!(
                                    e.downcast_ref::<ServerError>(),
                                    Some(&ServerError::Stopped),
                                    "unexpected error: {e:#}"
                                );
                                break;
                            }
                        }
                    }
                    answered
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        drop(server); // close + drain + join, racing the submit loops
        for h in clients {
            // Every client exits; whatever was accepted was answered.
            let _ = h.join().unwrap();
        }
    });
}

#[test]
fn infer_and_infer_async_report_identical_feature_length_errors() {
    // Regression: `infer_async` used to report a bare "bad feature
    // length" while `infer` named both lengths. All submission paths must
    // share the detailed message.
    let net = Arc::new(random_network(74, 8, 2, &[4, 2], 2, 2, 4));
    let server = serve(&net, &FabricOptions::new());
    let client = server.client();
    let e_sync = client.infer(vec![0.0; 3]).unwrap_err().to_string();
    let e_async = client.infer_async(vec![0.0; 3]).unwrap_err().to_string();
    let e_try = client.try_infer(vec![0.0; 3]).unwrap_err().to_string();
    assert_eq!(e_sync, "feature vector has 3 values, model expects 8");
    assert_eq!(e_async, e_sync);
    assert_eq!(e_try, e_sync);
}
