//! End-to-end observability tests: one compiled-and-served run must be
//! answerable from telemetry alone — per-opt-pass timing and op deltas
//! via the [`CompileReport`] (and its `.report.json` artifact sibling),
//! stage-split request latencies via the `neuralut_server_*` metrics
//! registry, with both surfaced through the Prometheus text and JSON
//! expositions.

use std::time::Duration;

use neuralut::fabric::{CompileReport, CompiledFabric, FabricOptions, Model, OptLevel};
use neuralut::luts::structured_network;
use neuralut::obs::{expo, MetricsRegistry};
use neuralut::util::json::Json;

#[test]
fn compile_report_is_coherent_and_matches_the_program() {
    let model = Model::from_network(structured_network(7, 16, 2, &[16, 8, 4], 3, 2, 4));
    let fabric = model
        .compile(&FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O2))
        .unwrap();
    let report = fabric.report();
    report.check().unwrap();
    assert!(!report.from_cache);
    assert_eq!(report.backend, "bitsliced");
    assert_eq!(report.opt_level, "O2");
    let names: Vec<&str> = report.passes.iter().map(|p| p.name.as_str()).collect();
    assert_eq!(names, ["lower", "simplify", "dce"]);
    // `lower` creates the netlist (enters with nothing), the chain ends
    // on the executed op count.
    assert_eq!(report.passes[0].ops_before, 0);
    assert_eq!(report.ops, fabric.num_word_ops().unwrap());
    assert!(report.total_s >= 0.0);
    assert!(report.levels > 0 && report.max_planes > 0 && report.max_wires > 0);
    // O0 runs no optimizer passes; its report still chains.
    let fabric_o0 = model
        .compile(&FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O0))
        .unwrap();
    fabric_o0.report().check().unwrap();
    assert_eq!(fabric_o0.report().passes.len(), 1, "only `lower` at O0");
}

#[test]
fn report_sidecar_round_trips_and_cache_hits_mark_from_cache() {
    let dir = std::env::temp_dir().join(format!("neuralut_obs_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.nfab");
    let model = Model::from_network(structured_network(9, 12, 2, &[8, 6, 3], 3, 2, 4));
    let opts = FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O2);

    let first = model.compile_cached(&opts, &path).unwrap();
    assert!(!first.report().from_cache);
    // save() left the report as a JSON sibling of the .nfab artifact.
    let sidecar = CompiledFabric::report_path(&path);
    let text = std::fs::read_to_string(&sidecar).unwrap();
    let parsed = CompileReport::from_json(&Json::parse(&text).unwrap()).unwrap();
    parsed.check().unwrap();
    assert_eq!(parsed.ops, first.report().ops);
    assert_eq!(parsed.passes.len(), first.report().passes.len());

    // Second compile hits the .nfab cache: nothing lowered or optimized
    // in this process, but the final shape is still reported.
    let second = model.compile_cached(&opts, &path).unwrap();
    assert!(second.report().from_cache);
    assert!(second.report().passes.is_empty());
    assert_eq!(second.report().ops, first.report().ops);
    second.report().check().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_served_run_is_answerable_from_telemetry_alone() {
    let model = Model::from_network(structured_network(5, 10, 2, &[8, 4], 3, 2, 4));
    let fabric = model
        .compile(
            &FabricOptions::new()
                .backend("bitsliced")
                .opt_level(OptLevel::O2)
                .workers(2)
                .max_batch(16)
                .batch_window(Duration::from_micros(100)),
        )
        .unwrap();
    let server = fabric.serve();
    let client = server.client();
    let n_req = 32usize;
    let mut pending = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let feats: Vec<f32> = (0..10).map(|j| ((i * 7 + j) % 13) as f32 / 13.0).collect();
        pending.push(client.infer_async(feats).unwrap());
    }
    for rx in pending {
        rx.recv().unwrap();
    }

    // Merge compile + runtime telemetry the way `neuralut stats` does.
    let reg = MetricsRegistry::new();
    fabric.report().export(&reg);
    let mut snap = reg.snapshot();
    snap.merge(server.metrics());

    // Compile side: per-pass wall time and op delta, final shape.
    for pass in ["lower", "simplify", "dce"] {
        assert!(
            snap.gauge("neuralut_compile_pass_seconds", &[("pass", pass)]).is_some(),
            "missing pass gauge for {pass}"
        );
    }
    assert_eq!(
        snap.gauge("neuralut_compile_ops", &[]).unwrap().value,
        fabric.num_word_ops().unwrap() as f64
    );

    // Runtime side: every request accounted for, all three latency
    // stages (plus end-to-end) populated with sane percentiles.
    assert_eq!(
        snap.counter("neuralut_server_requests_served_total", &[]).unwrap().value,
        n_req as u64
    );
    for name in [
        "neuralut_server_latency_us",
        "neuralut_server_queue_wait_us",
        "neuralut_server_batch_formation_us",
        "neuralut_server_execute_us",
    ] {
        let h = snap.histogram(name, &[]).unwrap();
        assert_eq!(h.count, n_req as u64, "{name}");
        assert!(h.percentile(0.50).is_finite(), "{name}");
    }
    assert_eq!(snap.gauge("neuralut_server_in_flight", &[]).unwrap().value, 0.0);

    // Both expositions carry the merged registry.
    let text = expo::to_prometheus(&snap);
    assert!(text.contains("neuralut_compile_pass_seconds{pass=\"simplify\"}"), "{text}");
    assert!(text.contains("neuralut_server_latency_us_bucket"), "{text}");
    assert!(text.contains("neuralut_server_requests_served_total 32"), "{text}");
    let json_text = expo::to_json(&snap).to_string();
    let parsed = Json::parse(&json_text).unwrap();
    assert!(!parsed.get("histograms").unwrap().as_arr().unwrap().is_empty());
}
