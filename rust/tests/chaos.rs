//! Fault-injection chaos suite for the supervised serving runtime.
//!
//! Every test arms a scoped fault plan (`util::faults`) and then proves
//! the two invariants the runtime guarantees under fire:
//!
//! 1. **no request ever hangs** — every accepted request resolves to a
//!    prediction or a typed [`ServerError`], watchdog-enforced;
//! 2. **survivors stay bit-exact** — any request that *is* answered with
//!    a prediction matches the scalar reference [`Simulator`], crashes or
//!    not.
//!
//! The sweep covers worker counts {1, 2, 8} against panics in batch
//! execution (the in-flight drop-guard + supervisor respawn path) and
//! panics inside the queue mutex (the poison-recovery path), plus
//! delay-injected deadline shedding, shutdown racing a crash storm, and
//! a torn report-sidecar write.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use neuralut::fabric::{CompiledFabric, CompileReport, FabricOptions, Model};
use neuralut::luts::{random_network, LutNetwork};
use neuralut::netlist::Simulator;
use neuralut::server::{Server, ServerError};
use neuralut::util::faults::{self, point};

/// Compile-and-serve through the unified fabric API.
fn serve(net: &Arc<LutNetwork>, opts: &FabricOptions) -> Server {
    Model::from_arc(net.clone()).compile(opts).unwrap().serve()
}

/// Deterministic per-(stream, request) feature vector.
fn feats_for(stream: usize, i: usize, n_feat: usize) -> Vec<f32> {
    (0..n_feat)
        .map(|j| ((stream * 31 + i * 7 + j) % 17) as f32 / 17.0)
        .collect()
}

/// Run `f` on a helper thread and panic if it does not finish in time —
/// the "no request ever hangs" invariant becomes a test failure instead
/// of a hung `cargo test`. A panic inside `f` is re-raised as itself.
fn with_watchdog<F: FnOnce() + Send + 'static>(label: &str, timeout: Duration, f: F) {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(timeout) {
        Ok(()) => {
            handle.join().unwrap();
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(panic) = handle.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("{label}: hung (watchdog fired after {timeout:?})");
        }
    }
}

/// Drive one server under an armed fault plan: submit `n` requests,
/// collect every reply, and enforce the two chaos invariants. Returns
/// (ok, errored, refused) counts.
fn drive_under_faults(
    net: &Arc<LutNetwork>,
    server: &Server,
    stream: usize,
    n: usize,
) -> (usize, usize, usize) {
    let sim = Simulator::new(net);
    let client = server.client();
    let mut pending = Vec::with_capacity(n);
    let mut refused = 0usize;
    for i in 0..n {
        let f = feats_for(stream, i, 8);
        let want = sim.simulate_batch(&f).predictions[0];
        // A crash storm that exhausts every worker slot's restart budget
        // closes the queue; from then on submission fails fast with
        // Stopped — a typed refusal, not a hang or a panic.
        match client.infer_async(f) {
            Ok(rx) => pending.push((rx, want)),
            Err(e) => {
                assert_eq!(
                    e.downcast_ref::<ServerError>(),
                    Some(&ServerError::Stopped),
                    "submission under faults may only refuse with Stopped: {e:#}"
                );
                refused += 1;
            }
        }
    }
    let mut ok = 0usize;
    let mut errored = 0usize;
    for (rx, want) in pending {
        match rx.recv() {
            Ok(reply) => {
                assert_eq!(
                    reply.prediction, want,
                    "survivor diverged from the scalar reference (stream {stream})"
                );
                ok += 1;
            }
            Err(e) => {
                let typed = e.downcast_ref::<ServerError>();
                assert!(
                    matches!(
                        typed,
                        Some(
                            ServerError::WorkerCrashed
                                | ServerError::Stopped
                                | ServerError::DeadlineExceeded
                        )
                    ),
                    "request resolved to an untyped error: {e:#}"
                );
                errored += 1;
            }
        }
    }
    assert_eq!(ok + errored + refused, n, "request accounting must close");
    (ok, errored, refused)
}

#[test]
fn worker_crash_storms_never_hang_and_survivors_stay_bit_exact() {
    with_watchdog("chaos-execute-panic", Duration::from_secs(240), || {
        let net = Arc::new(random_network(81, 8, 2, &[6, 3], 3, 2, 4));
        for (w, workers) in [1usize, 2, 8].into_iter().enumerate() {
            let guard =
                faults::arm_scoped("worker.execute:0.2:panic", 900 + w as u64).unwrap();
            let server = serve(
                &net,
                &FabricOptions::new()
                    .workers(workers)
                    .max_batch(8)
                    .batch_window(Duration::from_micros(100)),
            );
            let (ok, errored, _refused) = drive_under_faults(&net, &server, w, 300);
            assert!(
                guard.fired(point::WORKER_EXECUTE) >= 1,
                "the chaos plan never fired (workers={workers})"
            );
            assert!(errored >= 1, "an execute panic must fail some request");
            let s = server.stats();
            assert!(s.worker_panics >= 1, "supervisor missed the panic");
            assert_eq!(s.served, ok as u64, "served must count only real replies");
            drop(server);
            drop(guard);
        }
    });
}

#[test]
fn queue_pop_panics_poison_no_request_across_worker_counts() {
    with_watchdog("chaos-pop-panic", Duration::from_secs(240), || {
        let net = Arc::new(random_network(82, 8, 2, &[6, 3], 3, 2, 4));
        for (w, workers) in [1usize, 2, 8].into_iter().enumerate() {
            // The pop point fires *inside* the queue mutex, so every
            // firing poisons the lock; a modest probability still fires
            // constantly because idle workers poll pop on every wakeup.
            let guard = faults::arm_scoped("queue.pop:0.05:panic", 910 + w as u64).unwrap();
            let server = serve(
                &net,
                &FabricOptions::new()
                    .workers(workers)
                    .max_batch(8)
                    .batch_window(Duration::from_micros(100)),
            );
            let (ok, errored, refused) = drive_under_faults(&net, &server, 10 + w, 300);
            assert!(
                guard.fired(point::QUEUE_POP) >= 1,
                "the chaos plan never fired (workers={workers})"
            );
            // A pop panic fires before the request leaves the queue, so
            // the popped-at request itself is never lost; requests already
            // in the worker's forming batch are answered by the in-flight
            // guard. Either way the accounting closes: every request is
            // served (bit-exact) or typed-failed.
            assert_eq!(ok + errored + refused, 300);
            drop(server);
            drop(guard);
        }
    });
}

#[test]
fn injected_execute_delays_shed_expired_requests_not_fresh_ones() {
    with_watchdog("chaos-deadline-shed", Duration::from_secs(60), || {
        let net = Arc::new(random_network(83, 8, 2, &[6, 3], 3, 2, 4));
        // Every batch execution sleeps 30 ms; the server-wide default
        // deadline (threaded through FabricOptions, the same knob as
        // `request_timeout_ms` / NEURALUT_REQUEST_TIMEOUT_MS) is 5 ms.
        // The first batch per worker dequeues fresh and is served late;
        // everything queued behind it expires and must be shed at
        // dequeue, never executed.
        let guard = faults::arm_scoped("worker.execute:1:delay:30", 920).unwrap();
        let server = serve(
            &net,
            &FabricOptions::new()
                .workers(2)
                .max_batch(4)
                .batch_window(Duration::from_millis(1))
                .request_timeout(Duration::from_millis(5)),
        );
        let sim = Simulator::new(&net);
        let client = server.client();
        let n = 24usize;
        let mut pending = Vec::with_capacity(n);
        for i in 0..n {
            let f = feats_for(20, i, 8);
            let want = sim.simulate_batch(&f).predictions[0];
            pending.push((client.infer_async(f).unwrap(), want));
        }
        let mut ok = 0usize;
        let mut shed = 0usize;
        for (rx, want) in pending {
            match rx.recv() {
                Ok(reply) => {
                    assert_eq!(reply.prediction, want, "late survivor diverged");
                    ok += 1;
                }
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<ServerError>(),
                        Some(&ServerError::DeadlineExceeded),
                        "expired requests must shed with DeadlineExceeded: {e:#}"
                    );
                    shed += 1;
                }
            }
        }
        assert!(guard.fired(point::WORKER_EXECUTE) >= 1);
        assert!(ok >= 1, "requests dequeued before their deadline must be served");
        assert!(shed >= 1, "requests stuck behind a delayed batch must shed");
        let s = server.stats();
        assert_eq!(s.deadline_exceeded, shed as u64);
        assert_eq!(s.served, ok as u64);
        drop(server);
    });
}

#[test]
fn shutdown_under_crash_fire_joins_and_answers_everything() {
    with_watchdog("chaos-shutdown-under-fire", Duration::from_secs(120), || {
        let net = Arc::new(random_network(84, 8, 2, &[6, 3], 3, 2, 4));
        let guard = faults::arm_scoped("worker.execute:0.8:panic", 930).unwrap();
        let server = serve(
            &net,
            &FabricOptions::new()
                .workers(8)
                .max_batch(8)
                .batch_window(Duration::from_micros(100)),
        );
        let sim = Simulator::new(&net);
        let client = server.client();
        let mut pending = Vec::new();
        for i in 0..400usize {
            let f = feats_for(30, i, 8);
            let want = sim.simulate_batch(&f).predictions[0];
            match client.infer_async(f) {
                Ok(rx) => pending.push((rx, want)),
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<ServerError>(),
                        Some(&ServerError::Stopped),
                        "{e:#}"
                    );
                    break;
                }
            }
        }
        // Tear down while most worker slots are mid-crash/backoff/respawn.
        // Drop must close the queue, join every supervisor (including ones
        // sleeping in crash backoff) and answer the backlog — inside the
        // watchdog budget.
        drop(server);
        assert!(guard.fired(point::WORKER_EXECUTE) >= 1, "storm never fired");
        for (rx, want) in pending {
            match rx.recv() {
                Ok(reply) => assert_eq!(reply.prediction, want, "survivor diverged"),
                Err(e) => assert!(
                    matches!(
                        e.downcast_ref::<ServerError>(),
                        Some(ServerError::WorkerCrashed | ServerError::Stopped)
                    ),
                    "untyped error at shutdown: {e:#}"
                ),
            }
        }
        // The dead server refuses new work fast, with the explicit error.
        let err = client.infer(feats_for(30, 0, 8)).unwrap_err();
        assert_eq!(err.downcast_ref::<ServerError>(), Some(&ServerError::Stopped));
        drop(guard);
    });
}

#[test]
fn env_armed_faults_uphold_the_no_hang_contract() {
    // Only meaningful under the CI chaos leg, which arms NEURALUT_FAULTS
    // for the whole process; a no-op in a plain `cargo test` run. Unlike
    // the scoped tests above, this one runs under the *environment* plan,
    // proving the env arming surface end-to-end: whatever the matrix
    // injects, no request hangs, refusals are typed, survivors are
    // bit-exact, and a failing backend compile degrades instead of dying
    // (hence the non-default backend).
    let spec = std::env::var("NEURALUT_FAULTS").unwrap_or_default();
    if spec.trim().is_empty() {
        return;
    }
    with_watchdog("chaos-env-armed", Duration::from_secs(240), move || {
        assert!(faults::armed(), "NEURALUT_FAULTS='{spec}' did not arm");
        let net = Arc::new(random_network(86, 8, 2, &[6, 3], 3, 2, 4));
        for (w, workers) in [1usize, 2, 8].into_iter().enumerate() {
            let server = serve(
                &net,
                &FabricOptions::new()
                    .backend("bitsliced")
                    .workers(workers)
                    .max_batch(8)
                    .batch_window(Duration::from_micros(100)),
            );
            drive_under_faults(&net, &server, 40 + w, 200);
            drop(server);
        }
    });
}

#[test]
fn torn_report_sidecar_write_leaves_a_good_nfab_and_no_partial_report() {
    let net = Arc::new(random_network(85, 8, 2, &[6, 3], 3, 2, 4));
    let m = Model::from_arc(net);
    let path = std::env::temp_dir().join("neuralut_chaos_torn_report.nfab");
    let report_path = CompiledFabric::report_path(&path);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&report_path);
    let opts = FabricOptions::new().backend("bitsliced").fabric_cache(&path);
    // Skip-count 1: the first atomic write (the .nfab artifact itself)
    // succeeds, the second (the .report.json sidecar) dies between the
    // tmp write and the rename — a crash mid-save.
    let guard = faults::arm_scoped("artifact.write:1:error:1", 940).unwrap();
    let fabric = m.compile(&opts).unwrap();
    assert!(!fabric.degraded());
    assert_eq!(guard.fired(point::ARTIFACT_WRITE), 1);
    assert!(path.exists(), "the .nfab must land before the report write");
    assert!(
        !report_path.exists(),
        "a torn sidecar write must never leave a partial .report.json"
    );
    // The artifact the rename already published is fully loadable.
    m.load_fabric(&opts, &path).unwrap();
    drop(guard);
    // Healthy again: recompiling repopulates both files atomically and
    // the sidecar parses as a well-formed report. Re-arm a plan that can
    // never fire so a NEURALUT_FAULTS spec from the CI chaos matrix (the
    // plan `drop(guard)` just restored) cannot interfere with the
    // recovery phase.
    let _quiet = faults::arm_scoped("chaos.noop:0:error", 941).unwrap();
    let _ = std::fs::remove_file(&path);
    let second = m.compile(&opts).unwrap();
    assert!(path.exists() && report_path.exists());
    let parsed =
        CompileReport::from_json(&neuralut::util::json::from_file(&report_path).unwrap())
            .unwrap();
    parsed.check().unwrap();
    assert_eq!(parsed.backend, second.backend_name());
    assert!(parsed.degraded_from.is_none());
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&report_path);
}

/// Entries left in an AOT cache dir (empty when the dir was never even
/// created — a failure before any write is the cleanest "nothing
/// cached" of all).
fn cache_entries(dir: &std::path::Path) -> Vec<std::path::PathBuf> {
    match std::fs::read_dir(dir) {
        Ok(rd) => rd.map(|e| e.unwrap().path()).collect(),
        Err(_) => Vec::new(),
    }
}

#[test]
fn injected_aot_build_failures_degrade_to_the_interpreter_and_cache_nothing() {
    if !neuralut::engine::aot::toolchain_available() {
        eprintln!("skipping: no native toolchain (rustc/cc) on PATH");
        return;
    }
    let net = Arc::new(random_network(86, 8, 2, &[6, 3], 3, 2, 4));
    let m = Model::from_arc(net.clone());
    let sim = Simulator::new(&net);
    let x = feats_for(9, 0, 8);
    let want = sim.simulate_batch(&x);
    for (i, (spec, pt)) in [
        ("aot.codegen:1:error", point::AOT_CODEGEN),
        ("aot.cc:1:error", point::AOT_CC),
    ]
    .into_iter()
    .enumerate()
    {
        let dir = std::env::temp_dir().join(format!(
            "neuralut_chaos_aot_{i}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nfab = dir.join("net.nfab");
        let opts = FabricOptions::new().backend("aot-c").aot_cache_dir(&dir);
        let guard = faults::arm_scoped(spec, 950 + i as u64).unwrap();

        // The native build dies mid-pipeline: serving survives on the
        // word-parallel interpreter, the report names the backend that
        // was asked for, and the degraded fabric stays bit-exact.
        let fabric = m.compile(&opts).unwrap();
        assert!(guard.fired(pt) >= 1, "{spec}: fault never fired");
        assert!(fabric.degraded(), "{spec}");
        assert_eq!(fabric.report().degraded_from.as_deref(), Some("aot-c"), "{spec}");
        assert_eq!(fabric.backend_name(), "bitsliced", "{spec}");
        let got = fabric.session().infer_batch(&x).unwrap();
        assert_eq!(got.logit_codes, want.logit_codes, "{spec}: degraded parity");

        // Nothing was cached: no `.so`, no orphaned tmp files a crashed
        // compiler left behind to be mistaken for a good object later.
        let leftovers = cache_entries(&dir);
        assert!(
            leftovers.is_empty(),
            "{spec}: a failed build must cache nothing, found {leftovers:?}"
        );

        // A degraded fabric must not poison the `.nfab` cache either:
        // compile_cached serves it but refuses to persist it.
        let cached = m.compile_cached(&opts.clone().fabric_cache(&nfab), &nfab).unwrap();
        assert!(cached.degraded(), "{spec}");
        assert!(
            !nfab.exists(),
            "{spec}: a degraded fabric must never be written to the artifact cache"
        );
        drop(guard);

        // Healthy again (re-arm a plan that can never fire so a
        // NEURALUT_FAULTS spec from the CI chaos matrix cannot
        // interfere): the same options now build native code.
        let _quiet = faults::arm_scoped("chaos.noop:0:error", 960 + i as u64).unwrap();
        let healthy = m.compile(&opts).unwrap();
        assert!(!healthy.degraded(), "{spec}: recovery");
        assert_eq!(healthy.backend_name(), "aot-c", "{spec}: recovery");
        let got = healthy.session().infer_batch(&x).unwrap();
        assert_eq!(got.logit_codes, want.logit_codes, "{spec}: native parity");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn injected_dlopen_failure_degrades_but_the_published_object_stays_reusable() {
    if !neuralut::engine::aot::toolchain_available() {
        eprintln!("skipping: no native toolchain (rustc/cc) on PATH");
        return;
    }
    let net = Arc::new(random_network(87, 8, 2, &[6, 3], 3, 2, 4));
    let m = Model::from_arc(net.clone());
    let sim = Simulator::new(&net);
    let x = feats_for(10, 0, 8);
    let want = sim.simulate_batch(&x);
    let dir = std::env::temp_dir().join(format!("neuralut_chaos_aot_dl_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = FabricOptions::new().backend("aot-c").aot_cache_dir(&dir);

    // dlopen dies *after* the object was compiled and atomically
    // published. Serving degrades (the load contract failed) but the
    // object on disk is real and fingerprint-checked, so it is not junk.
    let guard = faults::arm_scoped("aot.dlopen:1:error", 970).unwrap();
    let fabric = m.compile(&opts).unwrap();
    assert!(guard.fired(point::AOT_DLOPEN) >= 1);
    assert!(fabric.degraded());
    assert_eq!(fabric.report().degraded_from.as_deref(), Some("aot-c"));
    assert_eq!(
        fabric.session().infer_batch(&x).unwrap().logit_codes,
        want.logit_codes
    );
    drop(guard);

    // Healthy retry: the published object is reused as-is — the AOT
    // pass tail is a lone `dlopen`, nothing recompiled.
    let _quiet = faults::arm_scoped("chaos.noop:0:error", 971).unwrap();
    let healthy = m.compile(&opts).unwrap();
    assert!(!healthy.degraded());
    assert_eq!(healthy.backend_name(), "aot-c");
    let tail: Vec<&str> = healthy
        .report()
        .passes
        .iter()
        .map(|p| p.name.as_str())
        .filter(|n| matches!(*n, "codegen" | "cc" | "dlopen"))
        .collect();
    assert_eq!(tail, ["dlopen"], "expected the cached object to be reused");
    assert_eq!(
        healthy.session().infer_batch(&x).unwrap().logit_codes,
        want.logit_codes
    );
    let _ = std::fs::remove_dir_all(&dir);
}
