//! Differential and artifact-discipline tests for the AOT native-code
//! backends (`aot`, `aot-c`): compiled `.so` objects must be bit-exact
//! against the reference `Simulator` on ragged batches across the repro
//! cases, opt levels, and lane widths; stale / truncated / mismatched
//! objects must be rejected and silently recompiled; `compile_cached`
//! must share one companion object across "processes"; and the serving
//! pool must produce the same predictions as the scalar path.
//!
//! Every test is gated on a native toolchain (`rustc` or `cc`) being on
//! PATH — without one it prints a skip note and passes, mirroring how
//! the backend itself degrades rather than fails. The two full-size
//! paper cases compile large C files; they only run when
//! `NEURALUT_AOT_FULL=1` (the CI `aot` job sets it) so a plain
//! `cargo test` stays fast.

use std::path::PathBuf;
use std::sync::Arc;

use neuralut::engine::aot::toolchain_available;
use neuralut::engine::{AotProvider, Emitter, OptLevel};
use neuralut::fabric::{companion_path, BackendRegistry, CompileReport, FabricOptions, Model};
use neuralut::luts::{random_network, structured_network, LutNetwork};
use neuralut::netlist::Simulator;

/// Skip (with a visible note) when no native toolchain exists.
fn no_toolchain() -> bool {
    if toolchain_available() {
        false
    } else {
        eprintln!("skipping: no native toolchain (rustc/cc) on PATH");
        true
    }
}

/// Fresh per-test scratch dir for `.so` / `.nfab` artifacts.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("neuralut_aot_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

/// Deterministic feature rows in [0, 1].
fn input_rows(input_size: usize, rows: usize, salt: usize) -> Vec<f32> {
    (0..rows * input_size)
        .map(|i| ((i * 7 + salt * 13) % 17) as f32 / 17.0)
        .collect()
}

/// The AOT-specific tail of a compile report's pass chain.
fn aot_passes(report: &CompileReport) -> Vec<String> {
    report
        .passes
        .iter()
        .map(|p| p.name.clone())
        .filter(|n| matches!(n.as_str(), "codegen" | "cc" | "dlopen"))
        .collect()
}

/// The small/medium repro cases (name, trained, input, bits, widths,
/// fan_in, beta) — same constructors and parameters as the bench suite.
fn small_cases() -> Vec<(&'static str, bool, usize, usize, Vec<usize>, usize, usize)> {
    vec![
        ("jsc-2l-trained", true, 16, 4, vec![32, 5], 3, 4),
        ("jsc-2l-random", false, 16, 4, vec![32, 5], 3, 4),
        ("logicnets-trained", true, 32, 1, vec![64, 32, 8], 4, 1),
        ("hdr-mini-trained", true, 196, 2, vec![64, 32, 10], 6, 2),
    ]
}

/// The two full-size paper cases, behind `NEURALUT_AOT_FULL=1`.
fn big_cases() -> Vec<(&'static str, bool, usize, usize, Vec<usize>, usize, usize)> {
    vec![
        ("jsc-5l-trained", true, 16, 4, vec![128, 128, 128, 64, 5], 3, 4),
        ("hdr-5l-paper-trained", true, 784, 2, vec![256, 100, 100, 100, 10], 6, 2),
    ]
}

fn build_case(
    (_name, trained, input, bits, widths, fan_in, beta): &(
        &'static str,
        bool,
        usize,
        usize,
        Vec<usize>,
        usize,
        usize,
    ),
) -> Arc<LutNetwork> {
    let net = if *trained {
        structured_network(1, *input, *bits, widths, *fan_in, *beta, 4)
    } else {
        random_network(1, *input, *bits, widths, *fan_in, *beta, 4)
    };
    Arc::new(net)
}

/// Compile `net` on the given backend at `opt` (cache dir supplied) and
/// assert bit-exactness against the simulator on ragged batch sizes.
fn assert_parity(
    net: &Arc<LutNetwork>,
    backend: &str,
    opt: OptLevel,
    cache: &std::path::Path,
    label: &str,
) -> CompileReport {
    let sim = Simulator::new(net);
    let model = Model::from_arc(net.clone());
    let fabric = model
        .compile(
            &FabricOptions::new()
                .backend(backend)
                .opt_level(opt)
                .aot_cache_dir(cache),
        )
        .unwrap_or_else(|e| panic!("{label}: compile failed: {e:#}"));
    assert_eq!(fabric.backend_name(), backend, "{label}");
    assert!(!fabric.degraded(), "{label}: degraded with a toolchain present");
    if let Err(e) = fabric.report().check() {
        panic!("{label}: inconsistent compile report: {e}");
    }
    let session = fabric.session();
    // Ragged sizes straddling the 64-sample word and lane-block edges.
    for (salt, rows) in [(0usize, 1usize), (1, 63), (2, 65), (3, 200)] {
        let x = input_rows(net.input_size, rows, salt);
        let got = session.infer_batch(&x).unwrap();
        let want = sim.simulate_batch(&x);
        assert_eq!(got.logit_codes, want.logit_codes, "{label}: {rows} rows");
        assert_eq!(got.predictions, want.predictions, "{label}: {rows} rows");
    }
    fabric.report().clone()
}

#[test]
fn aot_matches_the_simulator_across_cases_and_opt_levels() {
    if no_toolchain() {
        return;
    }
    let cache = tmp_dir("matrix");
    for case in &small_cases() {
        let net = build_case(case);
        for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let label = format!("{}@{opt}", case.0);
            let report = assert_parity(&net, "aot-c", opt, &cache, &label);
            // A fresh object was produced for each opt level (the
            // content fingerprint differs), never a cross-level reuse.
            assert_eq!(
                aot_passes(&report),
                ["codegen", "cc", "dlopen"],
                "{label}: expected a fresh native build"
            );
        }
    }
    // `aot` (Rust emitter) degrades to emitting C when rustc is missing,
    // so it is exercisable wherever `aot-c` is; one case suffices since
    // both share codegen and the ABI.
    let net = build_case(&small_cases()[0]);
    assert_parity(&net, "aot", OptLevel::O2, &cache, "jsc-2l-trained@aot");
    let _ = std::fs::remove_dir_all(&cache);
}

#[test]
fn aot_full_matrix_covers_the_paper_scale_cases() {
    if no_toolchain() {
        return;
    }
    if std::env::var("NEURALUT_AOT_FULL").map(|v| v != "1").unwrap_or(true) {
        eprintln!("skipping: full-size paper cases need NEURALUT_AOT_FULL=1");
        return;
    }
    let cache = tmp_dir("full");
    for case in &big_cases() {
        let net = build_case(case);
        for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let label = format!("{}@{opt}", case.0);
            assert_parity(&net, "aot-c", opt, &cache, &label);
        }
    }
    let _ = std::fs::remove_dir_all(&cache);
}

#[test]
fn lane_width_matrix_is_bit_exact_and_objects_are_disjoint() {
    if no_toolchain() {
        return;
    }
    let cache = tmp_dir("lanes");
    let net = Arc::new(random_network(71, 8, 2, &[6, 3], 3, 2, 4));
    let sim = Simulator::new(&net);
    let x = input_rows(8, 130 * 4 + 17, 5); // deep enough to shard at any width
    let want = sim.simulate_batch(&x);
    let registry = BackendRegistry::empty();
    for lanes in [1usize, 2, 4] {
        registry
            .register(
                &format!("aot-x{lanes}"),
                Arc::new(AotProvider::with_lanes(Emitter::C, lanes)),
            )
            .unwrap();
    }
    let model = Model::from_arc(net.clone());
    for lanes in [1usize, 2, 4] {
        let name = format!("aot-x{lanes}");
        let fabric = model
            .compile_with(
                &registry,
                &FabricOptions::new().backend(&name).opt_level(OptLevel::O2).aot_cache_dir(&cache),
            )
            .unwrap();
        assert_eq!(fabric.capabilities().word_lanes, lanes);
        let got = fabric.session().infer_batch(&x).unwrap();
        assert_eq!(got.logit_codes, want.logit_codes, "x{lanes} lanes");
        // Each width owns its own object file: the lane count is baked
        // into both the file name and the embedded metadata.
        let so = cache.join(format!("{:016x}.x{lanes}.aot-c.so", model.digest()));
        assert!(so.exists(), "missing {}", so.display());
    }
    let _ = std::fs::remove_dir_all(&cache);
}

#[test]
fn stale_or_corrupt_objects_are_rejected_and_silently_rebuilt() {
    if no_toolchain() {
        return;
    }
    let cache = tmp_dir("stale");
    let net = Arc::new(random_network(72, 8, 2, &[6, 3], 3, 2, 4));
    let model = Model::from_arc(net.clone());
    let first = assert_parity(&net, "aot-c", OptLevel::O2, &cache, "fresh");
    assert_eq!(aot_passes(&first), ["codegen", "cc", "dlopen"]);

    // Identical compile: the cached object is reused — dlopen only.
    let second = assert_parity(&net, "aot-c", OptLevel::O2, &cache, "cached");
    assert_eq!(aot_passes(&second), ["dlopen"], "expected a cache hit");

    // A different opt level maps to the same path (same digest, same
    // lanes) but a different program fingerprint: the stale object must
    // be rejected and rebuilt, never replayed.
    let other_level = assert_parity(&net, "aot-c", OptLevel::O0, &cache, "cross-level");
    assert_eq!(
        aot_passes(&other_level),
        ["codegen", "cc", "dlopen"],
        "an O2 object must not satisfy an O0 compile"
    );

    // Truncate the object: dlopen fails, the backend recompiles, and
    // results are still bit-exact.
    let so: Vec<PathBuf> = std::fs::read_dir(&cache)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|e| e == "so"))
        .collect();
    assert_eq!(so.len(), 1, "one digest+width maps to one object file");
    std::fs::write(&so[0], &[0x7f, b'E', b'L', b'F']).unwrap();
    let rebuilt = assert_parity(&net, "aot-c", OptLevel::O2, &cache, "truncated");
    assert_eq!(aot_passes(&rebuilt), ["codegen", "cc", "dlopen"]);

    // An object compiled from a *different* model copied over this
    // model's path carries the wrong digest/fingerprint: rejected.
    let other = Arc::new(random_network(73, 8, 2, &[6, 3], 3, 2, 4));
    assert_parity(&other, "aot-c", OptLevel::O2, &cache, "other-model");
    let paths: Vec<PathBuf> = std::fs::read_dir(&cache)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|e| e == "so"))
        .collect();
    assert_eq!(paths.len(), 2);
    let mine = paths
        .iter()
        .find(|p| p.to_string_lossy().contains(&format!("{:016x}", model.digest())))
        .unwrap();
    let theirs = paths.iter().find(|p| *p != mine).unwrap();
    std::fs::copy(theirs, mine).unwrap();
    let foreign = assert_parity(&net, "aot-c", OptLevel::O2, &cache, "foreign");
    assert_eq!(
        aot_passes(&foreign),
        ["codegen", "cc", "dlopen"],
        "another model's object must not be replayed"
    );
    let _ = std::fs::remove_dir_all(&cache);
}

#[test]
fn compile_cached_shares_the_companion_object_across_processes() {
    if no_toolchain() {
        return;
    }
    let dir = tmp_dir("companion");
    let nfab = dir.join("net.nfab");
    let net = Arc::new(random_network(74, 8, 2, &[6, 3], 3, 2, 4));
    let sim = Simulator::new(&net);
    let x = input_rows(8, 90, 6);
    let want = sim.simulate_batch(&x);
    let opts = FabricOptions::new().backend("aot-c").opt_level(OptLevel::O2);

    // "Process" one compiles and persists: the `.nfab` gains a companion
    // `.so` beside it, named by digest so stale siblings never alias.
    let model = Model::from_arc(net.clone());
    let fabric = model.compile_cached(&opts, &nfab).unwrap();
    assert!(!fabric.report().from_cache);
    assert_eq!(aot_passes(fabric.report()), ["codegen", "cc", "dlopen"]);
    assert!(nfab.exists());
    let so = companion_path(&nfab, model.digest(), "aot-c.so");
    assert!(so.exists(), "companion object missing at {}", so.display());

    // "Process" two loads both artifacts: netlist from the `.nfab`,
    // native code via dlopen only — nothing lowered, nothing compiled.
    let model2 = Model::from_arc(net.clone());
    let loaded = model2.compile_cached(&opts, &nfab).unwrap();
    assert!(loaded.report().from_cache, "expected an artifact load");
    assert_eq!(
        aot_passes(loaded.report()),
        ["dlopen"],
        "a second process must reuse the companion object"
    );
    let got = loaded.session().infer_batch(&x).unwrap();
    assert_eq!(got.logit_codes, want.logit_codes);

    // Delete just the companion: the `.nfab` still loads and the object
    // is rebuilt from its netlist — a missing companion is not fatal.
    std::fs::remove_file(&so).unwrap();
    let rebuilt = Model::from_arc(net.clone()).compile_cached(&opts, &nfab).unwrap();
    assert!(rebuilt.report().from_cache);
    assert_eq!(aot_passes(rebuilt.report()), ["codegen", "cc", "dlopen"]);
    assert!(so.exists(), "companion not regenerated");
    assert_eq!(rebuilt.session().infer_batch(&x).unwrap().logit_codes, want.logit_codes);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serving_on_native_code_matches_the_scalar_pool() {
    if no_toolchain() {
        return;
    }
    let cache = tmp_dir("serve");
    let net = Arc::new(structured_network(2, 16, 4, &[32, 5], 3, 4, 4));
    let sim = Simulator::new(&net);
    let model = Model::from_arc(net.clone());
    let fabric = model
        .compile(
            &FabricOptions::new()
                .backend("aot-c")
                .opt_level(OptLevel::O2)
                .aot_cache_dir(&cache)
                .workers(2),
        )
        .unwrap();
    let server = fabric.serve();
    let client = server.client();
    for i in 0..32 {
        let feats: Vec<f32> = (0..16).map(|j| ((i * 3 + j) % 11) as f32 / 11.0).collect();
        let want = sim.simulate_batch(&feats).predictions[0];
        assert_eq!(client.infer(feats).unwrap().prediction, want, "request {i}");
    }
    drop(server);
    let _ = std::fs::remove_dir_all(&cache);
}

#[test]
fn disabling_aot_degrades_to_the_interpreter() {
    // No toolchain needed: the disable check fires before any probe.
    // NEURALUT_AOT=off must never take serving down — the request
    // degrades to the interpreter and the report says so.
    let net = Arc::new(random_network(75, 8, 2, &[6, 3], 3, 2, 4));
    let model = Model::from_arc(net);
    let fabric = model
        .compile(&FabricOptions::new().backend("aot-c").aot_disabled(true))
        .unwrap();
    assert_eq!(fabric.backend_name(), "bitsliced");
    assert_eq!(fabric.report().degraded_from.as_deref(), Some("aot-c"));
}
