//! Property-based tests (mini-harness, `util::check`) over the Rust
//! substrates: netlist simulation, synthesis model, RTL packing, LUT
//! serialization, sparsity/wiring invariants, server batching.

use std::time::Duration;

use neuralut::engine::{lane_backend_name, LANE_WIDTHS};
use neuralut::fabric::{FabricOptions, Model, OptLevel};
use neuralut::luts::{random_network, structured_network, LutNetwork};
use neuralut::netlist::{quantize_input, Simulator};
use neuralut::nn::formulas;
use neuralut::rtl;
use neuralut::server::{ServerConfig, MAX_QUEUE_DEPTH, MAX_WORKERS};
use neuralut::synth::{self, boolfn, robdd};
use neuralut::util::check::{forall, forall_res};
use neuralut::util::rng::Rng;

fn arb_network(r: &mut Rng) -> LutNetwork {
    let input_size = 3 + r.below(12);
    let input_bits = 1 + r.below(3);
    let n_layers = 1 + r.below(3);
    let mut widths: Vec<usize> = (0..n_layers).map(|_| 2 + r.below(8)).collect();
    widths.push(2 + r.below(4)); // output layer
    let fan_in = 1 + r.below(4);
    let beta = 1 + r.below(3);
    random_network(r.next_u64(), input_size, input_bits, &widths, fan_in, beta, 4)
}

/// Like [`arb_network`] but alternating uniform-random tables with
/// trained-like (threshold/saturated) tables — the shapes the netlist
/// optimizer actually bites on.
fn arb_network_mixed(r: &mut Rng) -> LutNetwork {
    let input_size = 3 + r.below(12);
    let input_bits = 1 + r.below(3);
    let n_layers = 1 + r.below(3);
    let mut widths: Vec<usize> = (0..n_layers).map(|_| 2 + r.below(8)).collect();
    widths.push(2 + r.below(4));
    let fan_in = 1 + r.below(4);
    let beta = 1 + r.below(3);
    if r.below(2) == 0 {
        random_network(r.next_u64(), input_size, input_bits, &widths, fan_in, beta, 4)
    } else {
        structured_network(r.next_u64(), input_size, input_bits, &widths, fan_in, beta, 4)
    }
}

/// Ragged batch sizes straddling the 64-lane word boundary.
fn arb_ragged_batch(r: &mut Rng) -> usize {
    match r.below(4) {
        0 => 1 + r.below(63),
        1 => 64 * (1 + r.below(3)),
        2 => 64 * (1 + r.below(3)) + 1 + r.below(63),
        _ => 1 + r.below(200),
    }
}

#[test]
fn prop_simulator_predictions_within_class_range() {
    forall(
        0x51,
        40,
        |r| {
            let net = arb_network(r);
            let batch = 1 + r.below(32);
            let x: Vec<f32> =
                (0..batch * net.input_size).map(|_| r.f32()).collect();
            (net, x)
        },
        |(net, x)| {
            let sim = Simulator::new(net);
            let res = sim.simulate_batch(x);
            res.predictions.iter().all(|&p| (p as usize) < net.n_class)
                && res.latency_cycles == net.layers.len()
        },
    );
}

#[test]
fn prop_simulator_is_permutation_invariant_over_batch() {
    // Simulating [a, b] must equal simulating a and b separately —
    // the fabric is stateless across samples.
    forall_res(
        0x52,
        30,
        |r| {
            let net = arb_network(r);
            let x1: Vec<f32> = (0..net.input_size).map(|_| r.f32()).collect();
            let x2: Vec<f32> = (0..net.input_size).map(|_| r.f32()).collect();
            (net, x1, x2)
        },
        |(net, x1, x2)| {
            let sim = Simulator::new(net);
            let mut both = x1.clone();
            both.extend_from_slice(x2);
            let b = sim.simulate_batch(&both);
            let a1 = sim.simulate_batch(x1);
            let a2 = sim.simulate_batch(x2);
            if b.predictions[0] != a1.predictions[0]
                || b.predictions[1] != a2.predictions[0]
            {
                return Err("batch result differs from singles".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitsliced_engine_is_bit_exact_against_scalar_simulator() {
    // The compiled engine must reproduce the scalar fabric exactly —
    // logit codes and predictions — across fan-ins, bit-widths and batch
    // sizes that straddle the 64-lane word boundary (ragged tails).
    forall_res(
        0x5B,
        30,
        |r| {
            let net = arb_network(r);
            // 1..=200 covers sub-word, exact-word and multi-word batches;
            // force a few ragged-tail cases explicitly.
            let batch = match r.below(4) {
                0 => 1 + r.below(63),
                1 => 64 * (1 + r.below(3)),
                2 => 64 * (1 + r.below(3)) + 1 + r.below(63),
                _ => 1 + r.below(200),
            };
            let x: Vec<f32> =
                (0..batch * net.input_size).map(|_| r.f32()).collect();
            (net, x)
        },
        |(net, x)| {
            let sim = Simulator::new(net);
            let session = Model::from_network(net.clone())
                .compile(&FabricOptions::new().backend("bitsliced"))
                .map_err(|e| e.to_string())?
                .session();
            let a = sim.simulate_batch(x);
            let b = session.infer_batch(x).map_err(|e| e.to_string())?;
            if a.logit_codes != b.logit_codes {
                return Err("logit codes diverge".into());
            }
            if a.predictions != b.predictions {
                return Err("predictions diverge".into());
            }
            if a.latency_cycles != b.latency_cycles
                || a.total_cycles != b.total_cycles
            {
                return Err("pipeline accounting diverges".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_optimized_netlists_are_bit_exact_at_every_level() {
    // O0 (verbatim lowering), O1 (fold + DCE) and O2 (global CSE + plane
    // compaction) must all reproduce the scalar fabric exactly — logit
    // codes and predictions — on random *and* trained-like tables, across
    // ragged batches. The optimizer may only ever remove work.
    forall_res(
        0x60,
        24,
        |r| {
            let net = arb_network_mixed(r);
            let batch = arb_ragged_batch(r);
            let x: Vec<f32> = (0..batch * net.input_size).map(|_| r.f32()).collect();
            (net, x)
        },
        |(net, x)| {
            let sim = Simulator::new(net);
            let want = sim.simulate_batch(x);
            let model = Model::from_network(net.clone());
            let mut prev_ops = usize::MAX;
            for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
                let fabric = model
                    .compile(&FabricOptions::new().backend("bitsliced").opt_level(level))
                    .map_err(|e| e.to_string())?;
                let ops = fabric.num_word_ops().ok_or("no word ops")?;
                if ops > prev_ops {
                    return Err(format!("{level} grew the netlist: {ops} > {prev_ops}"));
                }
                prev_ops = ops;
                let got = fabric.session().infer_batch(x).map_err(|e| e.to_string())?;
                if got.logit_codes != want.logit_codes {
                    return Err(format!("{level}: logit codes diverge from scalar"));
                }
                if got.predictions != want.predictions {
                    return Err(format!("{level}: predictions diverge from scalar"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_every_lane_width_is_bit_exact_at_every_opt_level() {
    // The whole width family (64/128/256/512 samples per block) must
    // reproduce the scalar fabric exactly at O0, O1 and O2 on ragged
    // batches that straddle block boundaries of every width.
    forall_res(
        0x62,
        10,
        |r| {
            let net = arb_network_mixed(r);
            // Straddle the widest (512-sample) block boundary too.
            let batch = match r.below(4) {
                0 => 1 + r.below(63),
                1 => 128 * (1 + r.below(4)),
                2 => 128 * (1 + r.below(4)) + 1 + r.below(63),
                _ => 1 + r.below(600),
            };
            let x: Vec<f32> = (0..batch * net.input_size).map(|_| r.f32()).collect();
            (net, x)
        },
        |(net, x)| {
            let sim = Simulator::new(net);
            let want = sim.simulate_batch(x);
            let model = Model::from_network(net.clone());
            for lanes in LANE_WIDTHS {
                let backend = lane_backend_name(lanes).ok_or("unnamed width")?;
                for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
                    let got = model
                        .compile(&FabricOptions::new().backend(backend).opt_level(level))
                        .map_err(|e| e.to_string())?
                        .session()
                        .infer_batch(x)
                        .map_err(|e| e.to_string())?;
                    if got.logit_codes != want.logit_codes {
                        return Err(format!("{backend} {level}: logit codes diverge"));
                    }
                    if got.predictions != want.predictions {
                        return Err(format!("{backend} {level}: predictions diverge"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nfab_artifacts_round_trip_bit_exactly() {
    // A fabric saved by one "process" (CompiledFabric::save) and loaded
    // into a fresh Model (Model::load_fabric) serves identical outputs
    // with an identical op count — no recompilation, no drift.
    forall_res(
        0x61,
        12,
        |r| {
            let net = arb_network_mixed(r);
            let batch = 1 + r.below(150);
            let x: Vec<f32> = (0..batch * net.input_size).map(|_| r.f32()).collect();
            let level = match r.below(3) {
                0 => OptLevel::O0,
                1 => OptLevel::O1,
                _ => OptLevel::O2,
            };
            let lanes = LANE_WIDTHS[r.below(LANE_WIDTHS.len())];
            (net, x, level, lanes)
        },
        |(net, x, level, lanes)| {
            let backend = lane_backend_name(*lanes).ok_or("unnamed width")?;
            let opts = FabricOptions::new().backend(backend).opt_level(*level);
            let model = Model::from_network(net.clone());
            let fabric = model.compile(&opts).map_err(|e| e.to_string())?;
            let path = std::env::temp_dir().join(format!(
                "neuralut_prop_nfab_{}_{level}_x{lanes}.nfab",
                net.name.replace('-', "_")
            ));
            fabric.save(&path).map_err(|e| e.to_string())?;
            let fresh = Model::from_network(net.clone());
            let loaded = fresh.load_fabric(&opts, &path).map_err(|e| e.to_string())?;
            if loaded.num_word_ops() != fabric.num_word_ops() {
                return Err("op count changed across save/load".into());
            }
            if loaded.opt_level() != *level {
                return Err("opt level not preserved".into());
            }
            let a = fabric.session().infer_batch(x).map_err(|e| e.to_string())?;
            let b = loaded.session().infer_batch(x).map_err(|e| e.to_string())?;
            if a.logit_codes != b.logit_codes || a.predictions != b.predictions {
                return Err("loaded artifact diverges from the saved fabric".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_server_config_toml_roundtrips() {
    // Generated valid docs (all keys, shuffled order) parse back to
    // exactly the values written — including the new `opt_level` key in
    // both spellings.
    forall_res(
        0x5C,
        80,
        |r| {
            let workers = 1 + r.below(MAX_WORKERS);
            let queue_depth = 1 + r.below(4096);
            let max_batch = 1 + r.below(2048);
            let window_us = r.below(5000);
            let backend = if r.below(2) == 0 { "scalar" } else { "bitsliced" };
            let opt = r.below(3);
            let opt_line = if r.below(2) == 0 {
                format!("opt_level = \"O{opt}\"")
            } else {
                format!("opt_level = {opt}")
            };
            let mut lines = vec![
                format!("workers = {workers}"),
                format!("queue_depth = {queue_depth}"),
                format!("max_batch = {max_batch}"),
                format!("batch_window_us = {window_us}"),
                format!("backend = \"{backend}\"  # engine"),
                opt_line,
            ];
            r.shuffle(&mut lines);
            (lines.join("\n"), workers, queue_depth, max_batch, window_us, backend, opt)
        },
        |(doc, workers, queue_depth, max_batch, window_us, backend, opt)| {
            let cfg = ServerConfig::parse_toml(doc).map_err(|e| e.to_string())?;
            match cfg.opt_level {
                Some(level) if level.index() as usize == *opt => {}
                other => return Err(format!("opt_level {other:?} != O{opt}")),
            }
            if cfg.workers != *workers {
                return Err(format!("workers {} != {workers}", cfg.workers));
            }
            if cfg.queue_depth != *queue_depth {
                return Err(format!("queue_depth {} != {queue_depth}", cfg.queue_depth));
            }
            if cfg.max_batch != *max_batch {
                return Err(format!("max_batch {} != {max_batch}", cfg.max_batch));
            }
            if cfg.batch_window != Duration::from_micros(*window_us as u64) {
                return Err("batch_window did not round-trip".into());
            }
            if cfg.backend.as_str() != *backend {
                return Err(format!("backend {} != {backend}", cfg.backend));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_server_config_rejects_zero_absurd_and_unknown() {
    forall(
        0x5D,
        60,
        |r| match r.below(7) {
            0 => "workers = 0".to_string(),
            1 => format!("workers = {}", MAX_WORKERS + 1 + r.below(1_000_000)),
            2 => "queue_depth = 0".to_string(),
            3 => format!("queue_depth = {}", MAX_QUEUE_DEPTH + 1 + r.below(1_000_000)),
            4 => format!("wrokers = {}", 1 + r.below(8)), // typo'd key
            5 => "workers = -3".to_string(),
            _ => format!("queue_depth = \"{}\"", 1 + r.below(8)), // wrong type
        },
        |doc| ServerConfig::parse_toml(doc).is_err(),
    );
}

#[test]
fn prop_fabric_options_validation_matches_server_config_rules() {
    // The FabricOptions builder enforces the same ranges as the config
    // file parser: zero/absurd workers, queue depths and max batches are
    // compile errors; in-range sets (with either built-in backend, any
    // case/whitespace) compile.
    let model = Model::from_network(random_network(0x5E, 5, 2, &[3, 2], 2, 2, 4));
    forall(
        0x5E,
        40,
        |r| match r.below(8) {
            0 => (FabricOptions::new().workers(0), false),
            1 => (
                FabricOptions::new().workers(MAX_WORKERS + 1 + r.below(1_000_000)),
                false,
            ),
            2 => (FabricOptions::new().queue_depth(0), false),
            3 => (
                FabricOptions::new().queue_depth(MAX_QUEUE_DEPTH + 1 + r.below(1_000_000)),
                false,
            ),
            4 => (FabricOptions::new().max_batch(0), false),
            // Unknown backend names never compile, whatever the spelling.
            5 => (
                FabricOptions::new().backend(format!("no-such-backend-{}", r.below(100))),
                false,
            ),
            _ => {
                let name = if r.below(2) == 0 { " Scalar " } else { "BITSLICED" };
                (
                    FabricOptions::new()
                        .backend(name)
                        .workers(1 + r.below(MAX_WORKERS))
                        .queue_depth(1 + r.below(4096))
                        .max_batch(1 + r.below(1024)),
                    true,
                )
            }
        },
        |(opts, should_compile)| model.compile(opts).is_ok() == *should_compile,
    );
}

#[test]
fn prop_fabric_options_precedence_is_builder_env_config() {
    // The one resolution path: config file < env < builder, per field,
    // for every combination of present/absent layers.
    forall_res(
        0x5F,
        80,
        |r| {
            let env_engine = (r.below(2) == 0).then(|| " Bitsliced ".to_string());
            let env_workers = (r.below(2) == 0).then(|| (1 + r.below(9)).to_string());
            let has_cfg = r.below(2) == 0;
            let cfg_workers = 1 + r.below(9);
            let builder_workers = (r.below(2) == 0).then(|| 1 + r.below(9));
            (env_engine, env_workers, has_cfg, cfg_workers, builder_workers)
        },
        |(env_engine, env_workers, has_cfg, cfg_workers, builder_workers)| {
            let cfg = ServerConfig {
                workers: *cfg_workers,
                backend: "scalar".to_string(),
                ..Default::default()
            };
            let env = |key: &str| match key {
                "NEURALUT_ENGINE" => env_engine.clone(),
                "NEURALUT_WORKERS" => env_workers.clone(),
                _ => None,
            };
            let mut opts = FabricOptions::with_env(&env, has_cfg.then_some(&cfg))
                .map_err(|e| e.to_string())?;
            if let Some(w) = builder_workers {
                opts = opts.workers(*w);
            }
            // Backend: env beats config; unset everywhere -> default.
            let want_backend = if let Some(e) = env_engine {
                Some(e.as_str())
            } else if *has_cfg {
                Some("scalar")
            } else {
                None
            };
            if opts.get_backend() != want_backend {
                return Err(format!(
                    "backend {:?} != {want_backend:?}",
                    opts.get_backend()
                ));
            }
            // Workers: builder beats env beats config.
            let want_workers = (*builder_workers)
                .or(env_workers.as_ref().map(|w| w.parse::<usize>().unwrap()))
                .or(has_cfg.then_some(*cfg_workers));
            if opts.get_workers() != want_workers {
                return Err(format!(
                    "workers {:?} != {want_workers:?}",
                    opts.get_workers()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantize_input_monotone_and_bounded() {
    forall(
        0x53,
        300,
        |r| (r.f32() * 2.0 - 0.5, 1 + r.below(7)),
        |&(x, bits)| {
            let q = quantize_input(x, bits);
            let q2 = quantize_input(x + 0.01, bits);
            q <= q2 && (q as u32) < (1u32 << bits)
        },
    );
}

#[test]
fn prop_support_reduction_sound() {
    // Projecting onto the support and re-expanding preserves the function.
    forall_res(
        0x54,
        60,
        |r| {
            let k = 2 + r.below(7);
            let bits: Vec<u8> = (0..1usize << k)
                .map(|_| (r.next_u64() & 1) as u8)
                .collect();
            (bits, k)
        },
        |(bits, k)| {
            let sup = boolfn::support(bits, *k);
            let proj = boolfn::project(bits, *k, &sup);
            // evaluate both on all addresses
            for addr in 0..bits.len() {
                let mut paddr = 0usize;
                for (j, &v) in sup.iter().enumerate() {
                    if (addr >> v) & 1 == 1 {
                        paddr |= 1 << j;
                    }
                }
                if proj[paddr] != bits[addr] {
                    return Err(format!("mismatch at addr {addr}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cost_function_within_bounds() {
    forall(
        0x55,
        60,
        |r| {
            let k = 2 + r.below(11);
            let bits: Vec<u8> = (0..1usize << k)
                .map(|_| (r.next_u64() & 1) as u8)
                .collect();
            (bits, k)
        },
        |(bits, k)| {
            let (luts, depth) = synth::cost_function(bits, *k);
            let constant = bits.iter().all(|&b| b == bits[0]);
            if constant {
                luts == 0 && depth == 0
            } else {
                luts >= 1 && luts <= synth::rom_upper_bound(*k) && depth >= 1
            }
        },
    );
}

#[test]
fn prop_bdd_node_count_invariant_under_complement() {
    // ROBDD size of f and NOT f is identical (terminals excluded).
    forall(
        0x56,
        60,
        |r| {
            let k = 2 + r.below(9);
            let bits: Vec<u8> = (0..1usize << k)
                .map(|_| (r.next_u64() & 1) as u8)
                .collect();
            (bits, k)
        },
        |(bits, k)| {
            let comp: Vec<u8> = bits.iter().map(|&b| 1 - b).collect();
            robdd::node_count(bits, *k) == robdd::node_count(&comp, *k)
        },
    );
}

#[test]
fn prop_nlut_serialization_roundtrips() {
    forall_res(
        0x57,
        25,
        |r| arb_network(r),
        |net| {
            let path = std::env::temp_dir().join(format!(
                "neuralut_prop_{}.nlut",
                net.name.replace('-', "_")
            ));
            net.save(&path).map_err(|e| e.to_string())?;
            let back = LutNetwork::load(&path).map_err(|e| e.to_string())?;
            if back.num_luts() != net.num_luts() {
                return Err("lut count changed".into());
            }
            for (a, b) in back.layers.iter().zip(&net.layers) {
                if a.tables != b.tables || a.indices != b.indices {
                    return Err("payload changed".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rtl_hex_width_consistent() {
    forall(
        0x58,
        30,
        |r| {
            let net = arb_network(r);
            let row: Vec<f32> = (0..net.input_size).map(|_| r.f32()).collect();
            (net, row)
        },
        |(net, row)| {
            let h = rtl::pack_input_hex(net, row);
            h.len() == (net.input_size * net.input_bits).div_ceil(4)
        },
    );
}

#[test]
fn prop_table1_formula_consistency() {
    forall(
        0x59,
        300,
        |r| {
            let l = 1 + r.below(6);
            let divisors: Vec<usize> =
                (1..=l).filter(|d| l % d == 0).collect();
            let s = if r.below(3) == 0 {
                0
            } else {
                divisors[r.below(divisors.len())]
            };
            (1 + r.below(16), l, 1 + r.below(24), s)
        },
        |&(f, l, n, s)| {
            formulas::t_neuralut(f, l, n, s)
                == formulas::t_neuralut_structural(f, l, n, s)
        },
    );
}

#[test]
fn prop_synth_total_is_sum_of_layers() {
    forall(
        0x5A,
        15,
        |r| arb_network(r),
        |net| {
            let rep = synth::synthesize(net);
            rep.luts == rep.per_layer.iter().map(|l| l.luts).sum::<usize>()
                && rep.latency_cycles == net.layers.len()
                && (rep.area_delay - rep.luts as f64 * rep.latency_ns).abs()
                    < 1e-9
        },
    );
}
