//! Loopback integration tests for the network front door: concurrent
//! binary + HTTP clients against a multi-model server, bit-exactness vs
//! the scalar simulator, a mid-traffic hot-swap with zero dropped or
//! hung requests, typed `Overloaded` refusals (wire code 1 / HTTP 429)
//! under a full queue, the connection cap, and `/metrics` reporting
//! per-model served counts plus the swap event.
//!
//! Every test is watchdog-guarded so a hung connection fails fast, and
//! the tests serialize on one mutex: the overload test arms a
//! process-global fault plan (`worker.execute` delay) that must never
//! leak into a concurrently running test's worker pool.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use neuralut::fabric::FabricOptions;
use neuralut::luts::random_network;
use neuralut::net::{Frame, ModelManager, NetConfig, NetServer, WireClient, WireRefusal};
use neuralut::netlist::Simulator;
use neuralut::server::ServerError;
use neuralut::util::faults;
use neuralut::util::json::Json;

/// Serializes the suite: the fault plan armed by the overload test is
/// process-global and must not delay another test's workers.
static SERIAL: Mutex<()> = Mutex::new(());

/// Run `f` on a helper thread and panic if it does not finish in time —
/// turns a deadlock into a test failure instead of a hung `cargo test`.
/// A panic inside `f` is re-raised as itself, not mislabeled as a deadlock.
fn with_watchdog<F: FnOnce() + Send + 'static>(label: &str, timeout: Duration, f: F) {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(timeout) {
        Ok(()) => {
            handle.join().unwrap();
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(panic) = handle.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("{label}: deadlocked (watchdog fired after {timeout:?})");
        }
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("neuralut_net_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Deterministic feature vector `seed` of length `n`.
fn feats(seed: usize, n: usize) -> Vec<f32> {
    (0..n).map(|j| ((seed * 31 + j * 7) % 17) as f32 / 17.0).collect()
}

fn start(dir: &Path, opts: &FabricOptions, cap: usize) -> (Arc<ModelManager>, NetServer) {
    let mgr = ModelManager::open(dir, opts).unwrap();
    let srv = NetServer::start(
        mgr.clone(),
        &NetConfig { listen_addr: "127.0.0.1:0".into(), max_connections: cap },
    )
    .unwrap();
    (mgr, srv)
}

/// One raw HTTP exchange: write the request, read to EOF (requests all
/// carry `Connection: close`), return the full response text.
fn http(addr: SocketAddr, request: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    http(addr, &format!("GET {path} HTTP/1.1\r\nConnection: close\r\n\r\n"))
}

/// `POST /v1/infer` with a flat row or nested batch; returns the HTTP
/// status and the parsed JSON body.
fn http_infer(addr: SocketAddr, model: &str, rows: &[Vec<f32>]) -> (u16, Json) {
    let render = |row: &Vec<f32>| {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        format!("[{}]", cells.join(", "))
    };
    let features = if rows.len() == 1 {
        render(&rows[0])
    } else {
        let nested: Vec<String> = rows.iter().map(render).collect();
        format!("[{}]", nested.join(", "))
    };
    let body = format!("{{\"model\": \"{model}\", \"features\": {features}}}");
    let resp = http(
        addr,
        &format!(
            "POST /v1/infer HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    );
    (status_of(&resp), Json::parse(body_of(&resp)).unwrap())
}

fn status_of(resp: &str) -> u16 {
    resp.split_whitespace().nth(1).unwrap().parse().unwrap()
}

fn body_of(resp: &str) -> &str {
    let i = resp.find("\r\n\r\n").expect("response has a header/body split");
    &resp[i + 4..]
}

fn json_preds(body: &Json) -> Vec<u32> {
    body.get("predictions")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_f64().unwrap() as u32)
        .collect()
}

/// Poll until every connection is deregistered — dropped client sockets
/// surface as reader EOFs, so this converges fast unless something hung.
fn wait_drained(srv: &NetServer) {
    let t0 = Instant::now();
    while srv.active_connections() != 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "connections never drained");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn binary_and_http_clients_serve_two_models_bit_exact() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    with_watchdog("two models", Duration::from_secs(120), || {
        let dir = tmp_dir("two");
        let net_a = random_network(71, 8, 2, &[6, 3], 3, 2, 4);
        let net_b = random_network(72, 12, 2, &[8, 4], 3, 2, 4);
        net_a.save(&dir.join("a.nlut")).unwrap();
        net_b.save(&dir.join("b.nlut")).unwrap();
        let opts = FabricOptions::new().backend("bitsliced").workers(2);
        let (_mgr, srv) = start(&dir, &opts, 32);
        let addr = srv.local_addr();

        let mut served_rows = [0usize; 2]; // binary rows per model
        std::thread::scope(|scope| {
            let handles: Vec<_> = [(&net_a, "a", 8usize), (&net_b, "b", 12usize)]
                .into_iter()
                .enumerate()
                .map(|(which, (net, name, n_feat))| {
                    scope.spawn(move || {
                        // Binary client: mixed batch sizes, every reply
                        // bit-exact vs the scalar simulator.
                        let sim = Simulator::new(net);
                        let mut wc = WireClient::connect(addr).unwrap();
                        wc.set_read_timeout(Duration::from_secs(30)).unwrap();
                        let mut rows_sent = 0usize;
                        for i in 0..20 {
                            let rows = [1usize, 3, 5][i % 3];
                            let flat: Vec<f32> = (0..rows)
                                .flat_map(|r| feats(which * 100 + i * 10 + r, n_feat))
                                .collect();
                            let got = wc.infer(name, &flat, rows).unwrap();
                            let want = sim.simulate_batch(&flat).predictions;
                            assert_eq!(got, want, "model {name}, request {i}");
                            rows_sent += rows;
                        }
                        rows_sent
                    })
                })
                .collect();

            // Concurrent HTTP client against the same port.
            let h = scope.spawn(|| {
                let sim = Simulator::new(&net_a);
                for i in 0..6 {
                    let row = feats(1000 + i, 8);
                    let (status, body) = http_infer(addr, "a", &[row.clone()]);
                    assert_eq!(status, 200, "{body:?}");
                    assert_eq!(json_preds(&body), sim.simulate_batch(&row).predictions);
                    assert_eq!(body.get("rows").unwrap().as_usize().unwrap(), 1);
                }
                // Nested batch against the second model.
                let sim_b = Simulator::new(&net_b);
                let rows = vec![feats(2000, 12), feats(2001, 12)];
                let flat: Vec<f32> = rows.iter().flatten().copied().collect();
                let (status, body) = http_infer(addr, "b", &rows);
                assert_eq!(status, 200, "{body:?}");
                assert_eq!(json_preds(&body), sim_b.simulate_batch(&flat).predictions);

                let health = http_get(addr, "/healthz");
                assert_eq!(status_of(&health), 200);
                assert!(health.contains("ok: serving 2 models"), "{health}");

                let models = http_get(addr, "/v1/models");
                assert_eq!(status_of(&models), 200);
                let listing = Json::parse(body_of(&models)).unwrap();
                let names: Vec<String> = listing
                    .get("models")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|m| m.get("name").unwrap().as_str().unwrap().to_string())
                    .collect();
                assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
            });
            for (which, handle) in handles.into_iter().enumerate() {
                served_rows[which] = handle.join().unwrap();
            }
            h.join().unwrap();
        });

        // Every client closed; nothing may linger.
        wait_drained(&srv);

        // The scrape tells the per-model story: row counters under the
        // model label, protocol counters for both front-door paths.
        let scrape = http_get(addr, "/metrics");
        assert!(scrape.contains("neuralut_net_model_requests_total{model=\"a\"}"), "{scrape}");
        assert!(scrape.contains("neuralut_net_model_requests_total{model=\"b\"}"), "{scrape}");
        assert!(scrape.contains("neuralut_net_requests_total{proto=\"binary\"}"), "{scrape}");
        assert!(scrape.contains("neuralut_net_requests_total{proto=\"http\"}"), "{scrape}");

        let snap = srv.metrics();
        let model_rows = |name: &str| {
            snap.counter("neuralut_net_model_requests_total", &[("model", name)]).unwrap().value
        };
        // a also served 6 single HTTP rows, b a 2-row HTTP batch.
        assert_eq!(model_rows("a"), (served_rows[0] + 6) as u64);
        assert_eq!(model_rows("b"), (served_rows[1] + 2) as u64);
        assert_eq!(
            snap.counter("neuralut_net_requests_total", &[("proto", "binary")]).unwrap().value,
            40
        );

        drop(srv);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn hot_swap_mid_traffic_drops_nothing_and_is_observable() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    with_watchdog("hot swap", Duration::from_secs(120), || {
        let dir = tmp_dir("swap");
        let net_a = random_network(81, 8, 2, &[6, 3], 3, 2, 4);
        let net_b = random_network(181, 8, 2, &[6, 3], 3, 2, 4);
        net_a.save(&dir.join("m.nlut")).unwrap();
        let opts = FabricOptions::new().backend("bitsliced").workers(2);
        let (mgr, srv) = start(&dir, &opts, 32);
        let addr = srv.local_addr();
        mgr.start_watcher(Duration::from_millis(25));
        let digest_before = mgr.get("m").unwrap().digest();

        // Expected predictions for a fixed vector pool under both
        // generations — every mid-swap reply must match one of them.
        let vecs: Vec<Vec<f32>> = (0..16).map(|k| feats(k, 8)).collect();
        let flat: Vec<f32> = vecs.iter().flatten().copied().collect();
        let a_pred = Simulator::new(&net_a).simulate_batch(&flat).predictions;
        let b_pred = Simulator::new(&net_b).simulate_batch(&flat).predictions;

        let swapped = AtomicBool::new(false);
        let sent = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let traffic = scope.spawn(|| {
                let mut wc = WireClient::connect(addr).unwrap();
                wc.set_read_timeout(Duration::from_secs(30)).unwrap();
                let mut i = 0usize;
                loop {
                    let k = i % vecs.len();
                    // Zero dropped/hung: every request during the swap
                    // must come back served (a refusal fails the test).
                    let got = wc.infer("m", &vecs[k], 1).expect("request dropped during hot-swap");
                    assert!(
                        got[0] == a_pred[k] || got[0] == b_pred[k],
                        "reply {} matches neither generation for vector {k}",
                        got[0]
                    );
                    i += 1;
                    if swapped.load(Ordering::Acquire) && i >= 200 {
                        break;
                    }
                    assert!(i < 500_000, "swap never became visible to the traffic loop");
                }
                sent.store(i, Ordering::Release);
            });

            // Mid-traffic: overwrite the .nlut and let the digest watcher
            // pick it up; the old generation keeps serving until then.
            std::thread::sleep(Duration::from_millis(30));
            net_b.save(&dir.join("m.nlut")).unwrap();
            let t0 = Instant::now();
            while mgr.get("m").unwrap().generation() != 2 {
                assert!(t0.elapsed() < Duration::from_secs(30), "watcher never swapped");
                std::thread::sleep(Duration::from_millis(10));
            }
            swapped.store(true, Ordering::Release);
            traffic.join().unwrap();
        });
        assert!(sent.load(Ordering::Acquire) >= 200);

        // The new generation serves the new network's exact predictions.
        let after = mgr.get("m").unwrap();
        assert_eq!(after.generation(), 2);
        assert_ne!(after.digest(), digest_before);
        let mut wc = WireClient::connect(addr).unwrap();
        wc.set_read_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(wc.infer("m", &flat, vecs.len()).unwrap(), b_pred);
        drop(wc);

        // The swap event and per-model counts are on the scrape.
        let scrape = http_get(addr, "/metrics");
        assert!(scrape.contains("neuralut_net_hot_swaps_total{model=\"m\"}"), "{scrape}");
        assert!(scrape.contains("neuralut_net_model_requests_total{model=\"m\"}"), "{scrape}");
        let snap = srv.metrics();
        assert_eq!(
            snap.counter("neuralut_net_hot_swaps_total", &[("model", "m")]).unwrap().value,
            1
        );
        assert_eq!(
            snap.gauge("neuralut_net_model_generation", &[("model", "m")]).unwrap().value,
            2.0
        );

        mgr.stop_watcher();
        drop(srv);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn overload_unknown_model_and_malformed_frames_refuse_typed() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    with_watchdog("typed refusals", Duration::from_secs(120), || {
        let dir = tmp_dir("refuse");
        random_network(91, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("m.nlut")).unwrap();
        let opts = FabricOptions::new().backend("bitsliced").workers(1).queue_depth(1);
        let (mgr, srv) = start(&dir, &opts, 32);
        let addr = srv.local_addr();

        // Unknown model: wire code 5 on the binary path, 404 on HTTP.
        let mut wc = WireClient::connect(addr).unwrap();
        wc.set_read_timeout(Duration::from_secs(30)).unwrap();
        let err = wc.infer("ghost", &feats(0, 8), 1).unwrap_err();
        let refusal = err.downcast_ref::<WireRefusal>().expect("typed refusal");
        assert_eq!(refusal.code, 5, "{refusal}");
        assert!(refusal.message.contains("serving: m"), "{refusal}");
        let (status, body) = http_infer(addr, "ghost", &[feats(0, 8)]);
        assert_eq!(status, 404);
        assert_eq!(body.get("code").unwrap().as_usize().unwrap(), 5);
        assert_eq!(body.get("kind").unwrap().as_str().unwrap(), "unknown_model");

        // Overload: stall the single worker (every execute +400 ms) and
        // fill the depth-1 queue in-process, so admission control is
        // deterministically saturated when the network clients arrive.
        let m = mgr.get("m").unwrap();
        let guard = faults::arm_scoped("worker.execute:1:delay:400", 920).unwrap();
        let mut parked = Vec::new();
        let t_fill = Instant::now();
        loop {
            match m.client().try_infer(feats(1, 8)) {
                Ok(p) => parked.push(p),
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<ServerError>(),
                        Some(&ServerError::Overloaded),
                        "{e:#}"
                    );
                    // Durably full means one row executing under the
                    // delay *and* one parked in the depth-1 queue; a
                    // refusal before that can be the transient instant
                    // where the queue is full but the worker is idle and
                    // about to pop. Let the worker pop and keep filling.
                    if parked.len() >= 2 {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
            assert!(t_fill.elapsed() < Duration::from_secs(10), "queue never filled durably");
        }
        // Binary client: typed Overloaded error frame, wire code 1.
        let err = wc.infer("m", &feats(2, 8), 1).unwrap_err();
        let refusal = err.downcast_ref::<WireRefusal>().expect("typed refusal");
        assert_eq!(refusal.code, 1, "{refusal}");
        // HTTP client: 429 with the same stable code in the body.
        let (status, body) = http_infer(addr, "m", &[feats(3, 8)]);
        assert_eq!(status, 429, "{body:?}");
        assert_eq!(body.get("code").unwrap().as_usize().unwrap(), 1);
        assert_eq!(body.get("kind").unwrap().as_str().unwrap(), "overloaded");
        drop(guard);
        // The parked rows were admitted, so they must still be answered.
        for p in &parked {
            p.recv().unwrap();
        }

        // After the stall clears, the same connection serves again.
        assert_eq!(wc.infer("m", &feats(4, 8), 1).unwrap().len(), 1);
        drop(wc);

        // Malformed frame: error frame with id 0, code 6, then close —
        // never a hang, never silent.
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        raw.write_all(b"NLW1").unwrap();
        raw.write_all(&1u32.to_le_bytes()).unwrap(); // len = 1
        raw.write_all(&[0x7f]).unwrap(); // unknown frame kind
        let mut len_buf = [0u8; 4];
        raw.read_exact(&mut len_buf).unwrap();
        let mut payload = vec![0u8; u32::from_le_bytes(len_buf) as usize];
        raw.read_exact(&mut payload).unwrap();
        match Frame::decode(&payload).unwrap() {
            Frame::Error { id, code, message } => {
                assert_eq!(id, 0);
                assert_eq!(code, 6);
                assert!(message.contains("unknown frame kind"), "{message}");
            }
            other => panic!("expected an error frame, got {other:?}"),
        }
        assert!(
            matches!(raw.read(&mut len_buf), Ok(0) | Err(_)),
            "connection must close after a framing error"
        );

        // Refusals are visible per wire-code tag.
        let snap = srv.metrics();
        let refusals = |tag: &str| {
            snap.counter("neuralut_net_refusals_total", &[("code", tag)]).unwrap().value
        };
        assert_eq!(refusals("unknown_model"), 2);
        assert!(refusals("overloaded") >= 2, "wire + http refusals counted");
        assert!(refusals("bad_request") >= 1, "framing error counted");

        drop(srv);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn connection_cap_refuses_typed_and_recovers() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    with_watchdog("connection cap", Duration::from_secs(120), || {
        let dir = tmp_dir("cap");
        random_network(61, 8, 2, &[6, 3], 3, 2, 4).save(&dir.join("m.nlut")).unwrap();
        let opts = FabricOptions::new().backend("bitsliced").workers(1);
        let (_mgr, srv) = start(&dir, &opts, 2);
        let addr = srv.local_addr();

        // Two round trips pin two live connections at the cap.
        let mut c1 = WireClient::connect(addr).unwrap();
        c1.set_read_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c1.infer("m", &feats(0, 8), 1).unwrap().len(), 1);
        let mut c2 = WireClient::connect(addr).unwrap();
        c2.set_read_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c2.infer("m", &feats(1, 8), 1).unwrap().len(), 1);
        assert_eq!(srv.active_connections(), 2);

        // A third binary connection gets an unsolicited typed goodbye
        // (Overloaded, id 0), not a hang and not a silent close.
        let mut c3 = WireClient::connect(addr).unwrap();
        c3.set_read_timeout(Duration::from_secs(10)).unwrap();
        match c3.recv().unwrap() {
            Frame::Error { id, code, message } => {
                assert_eq!(id, 0);
                assert_eq!(code, 1, "connection-cap refusal is Overloaded");
                assert!(message.contains("connection limit"), "{message}");
            }
            other => panic!("expected a refusal frame, got {other:?}"),
        }
        drop(c3);

        // An HTTP probe over the cap gets a 429 with the JSON error body.
        let resp = http_get(addr, "/healthz");
        assert_eq!(status_of(&resp), 429, "{resp}");
        let body = Json::parse(body_of(&resp)).unwrap();
        assert_eq!(body.get("code").unwrap().as_usize().unwrap(), 1);

        // Freed slots admit new clients — the cap is a gate, not a latch.
        drop(c1);
        drop(c2);
        wait_drained(&srv);
        let mut c4 = WireClient::connect(addr).unwrap();
        c4.set_read_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c4.infer("m", &feats(2, 8), 1).unwrap().len(), 1);
        drop(c4);

        let snap = srv.metrics();
        assert_eq!(
            snap.counter("neuralut_net_connections_refused_total", &[]).unwrap().value,
            2
        );

        drop(srv);
        let _ = std::fs::remove_dir_all(&dir);
    });
}
