//! Tests of the unified inference API surface: a mock backend registered
//! by name drives both `session()` inference and a running `serve()`
//! pool bit-exactly against the scalar path; built-in backends agree
//! end-to-end; corrupt NLUT model files and corrupt/truncated/
//! wrong-digest `.nfab` compiled-fabric artifacts are rejected with
//! diagnosable errors; `Model::compile_cached` shares one precompiled
//! program across "processes".

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use neuralut::engine::{FabricProgram, InferenceBackend, OptLevel, ScalarProgram};
use neuralut::fabric::{
    BackendProvider, BackendRegistry, BatchAffinity, Capabilities, CompileCost, FabricOptions,
    Model, ProviderCtx,
};
use neuralut::luts::{random_network, structured_network, LutNetwork};
use neuralut::netlist::{SimResult, Simulator};

// ---------------------------------------------------------------------------
// Mock backend: scalar semantics under a new registry name, with compile
// and executor-spawn counters so sharing is observable.

struct MockProgram {
    inner: ScalarProgram,
    spawned: Arc<AtomicUsize>,
}

struct MockExecutor {
    inner: Box<dyn InferenceBackend>,
}

impl InferenceBackend for MockExecutor {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn latency_cycles(&self) -> usize {
        self.inner.latency_cycles()
    }

    fn run_batch(&self, x: &[f32]) -> SimResult {
        self.inner.run_batch(x)
    }
}

impl FabricProgram for MockProgram {
    fn executor(&self) -> Box<dyn InferenceBackend> {
        self.spawned.fetch_add(1, Ordering::SeqCst);
        Box::new(MockExecutor { inner: self.inner.executor() })
    }
}

/// Mock provider: compile and executor-spawn counters shared with every
/// program it builds.
struct MockProvider {
    compiled: Arc<AtomicUsize>,
    spawned: Arc<AtomicUsize>,
}

impl BackendProvider for MockProvider {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            signed_hidden: true,
            batch_affinity: BatchAffinity::Single,
            compile_cost: CompileCost::Free,
            persistable: false,
            word_lanes: 0,
            fallback: None,
        }
    }

    fn compile(
        &self,
        net: Arc<LutNetwork>,
        _opt: OptLevel,
        _ctx: &ProviderCtx,
    ) -> neuralut::Result<Arc<dyn FabricProgram>> {
        self.compiled.fetch_add(1, Ordering::SeqCst);
        Ok(Arc::new(MockProgram {
            inner: ScalarProgram::new(net),
            spawned: self.spawned.clone(),
        }))
    }
}

/// Register the mock once per process; returns (compile count, spawn
/// count) shared with every program the provider builds.
fn register_mock() -> (Arc<AtomicUsize>, Arc<AtomicUsize>) {
    use std::sync::OnceLock;
    static COUNTERS: OnceLock<(Arc<AtomicUsize>, Arc<AtomicUsize>)> = OnceLock::new();
    COUNTERS
        .get_or_init(|| {
            let compiled = Arc::new(AtomicUsize::new(0));
            let spawned = Arc::new(AtomicUsize::new(0));
            BackendRegistry::global()
                .register(
                    "mock",
                    Arc::new(MockProvider {
                        compiled: compiled.clone(),
                        spawned: spawned.clone(),
                    }),
                )
                .expect("mock registers once");
            (compiled, spawned)
        })
        .clone()
}

#[test]
fn registered_mock_backend_drives_session_and_serve_bit_exactly() {
    let (compiled, spawned) = register_mock();
    let net = Arc::new(random_network(81, 8, 2, &[6, 3], 3, 2, 4));
    let sim = Simulator::new(&net);
    let model = Model::from_arc(net.clone());

    // The mock is selectable by name — case/whitespace-insensitively —
    // exactly like a built-in.
    let fabric = model
        .compile(&FabricOptions::new().backend(" MOCK ").workers(3))
        .unwrap();
    assert_eq!(fabric.backend_name(), "mock");
    assert_eq!(fabric.capabilities().compile_cost, CompileCost::Free);
    assert_eq!(compiled.load(Ordering::SeqCst), 1, "factory ran exactly once");

    // session(): in-process inference, bit-exact vs the scalar fabric.
    let session = fabric.session();
    assert_eq!(session.backend_name(), "mock");
    let x: Vec<f32> = (0..8 * 70).map(|i| (i % 11) as f32 / 11.0).collect();
    let got = session.infer_batch(&x).unwrap();
    let want = sim.simulate_batch(&x);
    assert_eq!(got.logit_codes, want.logit_codes);
    assert_eq!(got.predictions, want.predictions);

    // serve(): a running worker pool over the same compiled program.
    let server = fabric.serve();
    assert_eq!(server.workers(), 3);
    let client = server.client();
    for i in 0..32 {
        let feats: Vec<f32> = (0..8).map(|j| ((i + j) % 9) as f32 / 9.0).collect();
        let want = sim.simulate_batch(&feats).predictions[0];
        assert_eq!(client.infer(feats).unwrap().prediction, want);
    }
    drop(server);
    // One session + three workers spawned executors; nothing recompiled.
    assert_eq!(spawned.load(Ordering::SeqCst), 4);
    assert_eq!(compiled.load(Ordering::SeqCst), 1);
    // The registry lists the mock alongside the built-ins, and the
    // unknown-name error cites it.
    assert!(BackendRegistry::global().names().contains(&"mock".to_string()));
    let err = model
        .compile(&FabricOptions::new().backend("fpga"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown backend 'fpga'"), "{err}");
    assert!(err.contains("mock"), "{err}");
}

#[test]
fn builtin_backends_are_bit_exact_through_sessions_and_serving() {
    let net = Arc::new(random_network(82, 7, 2, &[5, 3], 2, 2, 4));
    let sim = Simulator::new(&net);
    let model = Model::from_arc(net.clone());
    let x: Vec<f32> = (0..7 * 90).map(|i| (i % 13) as f32 / 13.0).collect();
    let want = sim.simulate_batch(&x);
    for backend in ["scalar", "bitsliced"] {
        let fabric = model
            .compile(&FabricOptions::new().backend(backend).workers(2))
            .unwrap();
        let got = fabric.session().infer_batch(&x).unwrap();
        assert_eq!(got.logit_codes, want.logit_codes, "{backend} session");
        let server = fabric.serve();
        let client = server.client();
        for i in 0..16 {
            let feats: Vec<f32> = (0..7).map(|j| ((i + j) % 5) as f32 / 5.0).collect();
            let one = sim.simulate_batch(&feats).predictions[0];
            assert_eq!(client.infer(feats).unwrap().prediction, one, "{backend} serve");
        }
    }
}

// ---------------------------------------------------------------------------
// Corrupt NLUT files are rejected with errors that name the path, the
// expected vs. actual values, and the file length.

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("neuralut_fabric_{name}.nlut"))
}

#[test]
fn nlut_load_rejects_bad_magic_with_expected_and_actual() {
    let path = tmp("bad_magic");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 32]);
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", LutNetwork::load(&path).unwrap_err());
    assert!(err.contains("bad NLUT magic 0xDEADBEEF"), "{err}");
    assert!(err.contains("0x4E4C5554"), "{err}");
    assert!(err.contains(&path.display().to_string()), "{err}");
    assert!(err.contains("40 bytes"), "{err}");
}

#[test]
fn nlut_load_rejects_bad_version_with_expected_and_actual() {
    let path = tmp("bad_version");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&0x4E4C5554u32.to_le_bytes()); // good magic
    bytes.extend_from_slice(&99u32.to_le_bytes()); // unsupported version
    bytes.extend_from_slice(&[0u8; 8]);
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", LutNetwork::load(&path).unwrap_err());
    assert!(err.contains("unsupported NLUT version 99"), "{err}");
    assert!(err.contains("version 1"), "{err}");
    assert!(err.contains(&path.display().to_string()), "{err}");
}

#[test]
fn nlut_load_reports_truncated_header_with_offset_and_length() {
    let path = tmp("trunc_header");
    // Magic plus half a version field: 6 bytes.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&0x4E4C5554u32.to_le_bytes());
    bytes.extend_from_slice(&[1u8, 0]);
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", LutNetwork::load(&path).unwrap_err());
    assert!(err.contains("truncated NLUT file"), "{err}");
    assert!(err.contains("version"), "{err}");
    assert!(err.contains("offset 4"), "{err}");
    assert!(err.contains("file is 6 bytes"), "{err}");
}

#[test]
fn nlut_load_rejects_absurd_header_fields_without_panicking() {
    // in_bits = 16 with fan_in = 4 would shift-overflow
    // `1 << (in_bits * fan_in)` without the header guards.
    let path = tmp("absurd_header");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&0x4E4C5554u32.to_le_bytes());
    bytes.extend_from_slice(&1u32.to_le_bytes()); // version
    bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len = 1
    bytes.push(b'x');
    for v in [4u32, 2, 2, 1] {
        // input_size, input_bits, n_class, n_layers
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    let header_len = bytes.len();
    // layer 0: num_luts=2, fan_in=4, in_bits=16, out_bits=4, signed=0.
    for v in [2u32, 4, 16, 4, 0] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", LutNetwork::load(&path).unwrap_err());
    assert!(err.contains("in_bits = 16"), "{err}");

    // A huge claimed num_luts in a tiny file is rejected against the
    // actual file length before any allocation is attempted.
    let path = tmp("absurd_numluts");
    bytes.truncate(header_len);
    for v in [u32::MAX, 2, 2, 4, 0] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", LutNetwork::load(&path).unwrap_err());
    assert!(err.contains("truncated NLUT file"), "{err}");
    assert!(err.contains("claims 4294967295"), "{err}");
}

#[test]
fn nlut_load_reports_truncation_inside_the_payload() {
    // A valid file cut short mid-tables must say what was being read and
    // how big the file actually is.
    let net = random_network(83, 6, 2, &[4, 2], 2, 2, 4);
    let full_path = tmp("full");
    net.save(&full_path).unwrap();
    let mut bytes = std::fs::read(&full_path).unwrap();
    let cut_len = bytes.len() - 3;
    bytes.truncate(cut_len);
    let path = tmp("trunc_payload");
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", LutNetwork::load(&path).unwrap_err());
    assert!(err.contains("truncated NLUT file"), "{err}");
    assert!(err.contains(&format!("file is {cut_len} bytes")), "{err}");
    // And the untruncated file still loads.
    assert!(LutNetwork::load(&full_path).is_ok());
}

// ---------------------------------------------------------------------------
// .nfab compiled-fabric artifacts: compile-once/serve-many across
// "processes", with corrupt/truncated/stale artifacts rejected loudly.

fn nfab(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("neuralut_fabric_{name}.nfab"))
}

#[test]
fn compile_cached_shares_one_precompiled_program_across_processes() {
    let net = structured_network(90, 10, 2, &[12, 6, 3], 3, 2, 4);
    let x: Vec<f32> = (0..10 * 130).map(|i| (i % 17) as f32 / 17.0).collect();
    let opts = FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O2);
    let path = nfab("cached");
    let _ = std::fs::remove_file(&path);

    // "Process" A compiles and populates the cache.
    let a = Model::from_network(net.clone());
    let fab_a = a.compile_cached(&opts, &path).unwrap();
    assert!(path.exists(), "first compile_cached must write the artifact");
    let bytes_after_first = std::fs::read(&path).unwrap();

    // "Process" B (a fresh Model over the same network) loads it — same
    // program, bit-exact outputs, artifact untouched.
    let b = Model::from_network(net.clone());
    let fab_b = b.compile_cached(&opts, &path).unwrap();
    assert_eq!(fab_a.num_word_ops(), fab_b.num_word_ops());
    assert_eq!(fab_b.opt_level(), OptLevel::O2);
    let ra = fab_a.session().infer_batch(&x).unwrap();
    let rb = fab_b.session().infer_batch(&x).unwrap();
    assert_eq!(ra.logit_codes, rb.logit_codes);
    assert_eq!(ra.predictions, rb.predictions);
    assert_eq!(std::fs::read(&path).unwrap(), bytes_after_first,
               "a cache hit must not rewrite the artifact");
    // And both agree with the scalar fabric.
    let sim = Simulator::new(&net);
    assert_eq!(sim.simulate_batch(&x).logit_codes, rb.logit_codes);

    // A *different* model against the same path is stale: recompiled and
    // overwritten, never silently served.
    let other_net = structured_network(91, 10, 2, &[12, 6, 3], 3, 2, 4);
    let other = Model::from_network(other_net.clone());
    let fab_o = other.compile_cached(&opts, &path).unwrap();
    assert_ne!(std::fs::read(&path).unwrap(), bytes_after_first,
               "stale artifact must be rewritten");
    let want = Simulator::new(&other_net).simulate_batch(&x);
    assert_eq!(fab_o.session().infer_batch(&x).unwrap().logit_codes,
               want.logit_codes);
}

#[test]
fn nfab_load_rejects_bad_magic_version_and_truncation_with_offsets() {
    let net = random_network(92, 8, 2, &[6, 3], 3, 2, 4);
    let model = Model::from_network(net);
    let opts = FabricOptions::new().backend("bitsliced");
    let path = nfab("good");
    model.compile(&opts).unwrap().save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Bad magic: expected-vs-actual, path, length.
    let bad = nfab("bad_magic");
    let mut bytes = good.clone();
    bytes[..4].copy_from_slice(&0xDEADBEEFu32.to_le_bytes());
    std::fs::write(&bad, &bytes).unwrap();
    let err = format!("{:#}", model.load_fabric(&opts, &bad).unwrap_err());
    assert!(err.contains("bad .nfab magic 0xDEADBEEF"), "{err}");
    assert!(err.contains("0x4E464142"), "{err}");
    assert!(err.contains(&bad.display().to_string()), "{err}");

    // Unsupported version.
    let bad = nfab("bad_version");
    let mut bytes = good.clone();
    bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&bad, &bytes).unwrap();
    let err = format!("{:#}", model.load_fabric(&opts, &bad).unwrap_err());
    assert!(err.contains("unsupported .nfab version 99"), "{err}");
    assert!(err.contains("version 3"), "{err}");

    // Truncation mid-payload names the field, offset and file length.
    let bad = nfab("truncated");
    let cut = good.len() - 7;
    std::fs::write(&bad, &good[..cut]).unwrap();
    let err = format!("{:#}", model.load_fabric(&opts, &bad).unwrap_err());
    assert!(err.contains("truncated .nfab artifact"), "{err}");
    assert!(err.contains(&format!("file is {cut} bytes")), "{err}");

    // An absurd claimed op count is rejected against the remaining file
    // length before any allocation. The first level's op count sits right
    // after magic/version, the artifact-kind byte, name, digest, opt
    // level, lane width, level count and the 12 bytes of level metadata.
    let bad = nfab("absurd_ops");
    let mut bytes = good.clone();
    let name_len = u32::from_le_bytes(bytes[9..13].try_into().unwrap()) as usize;
    let ops_off = 13 + name_len + 8 + 4 + 4 + 4 + 12;
    bytes[ops_off..ops_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&bad, &bytes).unwrap();
    let err = format!("{:#}", model.load_fabric(&opts, &bad).unwrap_err());
    assert!(err.contains("claims 4294967295 ops"), "{err}");

    // The untouched artifact still loads.
    assert!(model.load_fabric(&opts, &path).is_ok());
}

#[test]
fn nfab_load_rejects_wrong_model_backend_and_opt_level() {
    let net = random_network(93, 8, 2, &[6, 3], 3, 2, 4);
    let model = Model::from_network(net);
    let opts = FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O1);
    let path = nfab("strict");
    model.compile(&opts).unwrap().save(&path).unwrap();

    // Wrong model (digest mismatch).
    let other = Model::from_network(random_network(94, 8, 2, &[6, 3], 3, 2, 4));
    let err = format!("{:#}", other.load_fabric(&opts, &path).unwrap_err());
    assert!(err.contains("digest"), "{err}");

    // Backend pinned to something else than the artifact records.
    let err = format!(
        "{:#}",
        model
            .load_fabric(&FabricOptions::new().backend("scalar"), &path)
            .unwrap_err()
    );
    assert!(err.contains("compiled by backend 'bitsliced'"), "{err}");
    assert!(err.contains("'scalar'"), "{err}");

    // Opt level pinned to something else than the artifact records.
    let err = format!(
        "{:#}",
        model
            .load_fabric(
                &FabricOptions::new().backend("bitsliced").opt_level(OptLevel::O2),
                &path
            )
            .unwrap_err()
    );
    assert!(err.contains("compiled at O1"), "{err}");
    assert!(err.contains("O2"), "{err}");

    // Unpinned options accept the artifact as recorded.
    let loaded = model.load_fabric(&FabricOptions::new().backend("bitsliced"), &path).unwrap();
    assert_eq!(loaded.opt_level(), OptLevel::O1);
    assert_eq!(loaded.backend_name(), "bitsliced");
}

#[test]
fn save_refuses_non_persistable_backends() {
    let model = Model::from_network(random_network(95, 6, 2, &[4, 2], 2, 2, 4));
    let fabric = model.compile(&FabricOptions::new()).unwrap(); // scalar
    let err = fabric.save(&nfab("scalar")).unwrap_err().to_string();
    assert!(err.contains("persistable"), "{err}");
    assert!(err.contains("scalar"), "{err}");
}

// ---------------------------------------------------------------------------
// Wide-plane artifacts: the lane width is part of the format, not a
// runtime choice — replays under a different width must be refused.

#[test]
fn nfab_round_trips_every_lane_width_and_rejects_width_patches() {
    let net = random_network(96, 8, 2, &[6, 3], 3, 2, 4);
    let model = Model::from_network(net.clone());
    let x: Vec<f32> = (0..8 * 100).map(|i| (i % 19) as f32 / 19.0).collect();
    let want = Simulator::new(&net).simulate_batch(&x);

    for backend in ["bitsliced", "bitsliced-x2", "bitsliced-x4", "bitsliced-x8"] {
        let opts = FabricOptions::new().backend(backend).opt_level(OptLevel::O2);
        let path = nfab(&format!("width_{backend}"));
        model.compile(&opts).unwrap().save(&path).unwrap();
        let loaded = model.load_fabric(&opts, &path).unwrap();
        assert_eq!(loaded.backend_name(), backend);
        let got = loaded.session().infer_batch(&x).unwrap();
        assert_eq!(got.logit_codes, want.logit_codes, "{backend}");
        assert_eq!(got.predictions, want.predictions, "{backend}");
    }

    // Byte-patch an x2 artifact's lane-width field to claim 4 words: the
    // x2 backend must refuse to replay it rather than mis-stride planes.
    let x2 = nfab("width_bitsliced-x2");
    let mut bytes = std::fs::read(&x2).unwrap();
    let name_len = u32::from_le_bytes(bytes[9..13].try_into().unwrap()) as usize;
    let lanes_off = 13 + name_len + 8 + 4;
    assert_eq!(
        u32::from_le_bytes(bytes[lanes_off..lanes_off + 4].try_into().unwrap()),
        2,
        "lane-width field not where the layout says it is"
    );
    bytes[lanes_off..lanes_off + 4].copy_from_slice(&4u32.to_le_bytes());
    let bad = nfab("width_patched");
    std::fs::write(&bad, &bytes).unwrap();
    let err = format!(
        "{:#}",
        model
            .load_fabric(&FabricOptions::new().backend("bitsliced-x2"), &bad)
            .unwrap_err()
    );
    assert!(err.contains("4-word plane format"), "{err}");
    assert!(err.contains("2-word planes"), "{err}");

    // Pinning a different width against an honest artifact fails the
    // same way before any plane is touched.
    let err = format!(
        "{:#}",
        model
            .load_fabric(&FabricOptions::new().backend("bitsliced-x4"), &x2)
            .unwrap_err()
    );
    assert!(err.contains("bitsliced-x2"), "{err}");
}

#[test]
fn bitsliced_auto_resolves_before_anything_is_persisted() {
    use neuralut::engine::{detect_lane_words, lane_backend_name};
    let net = random_network(97, 8, 2, &[6, 3], 3, 2, 4);
    let model = Model::from_network(net.clone());
    let x: Vec<f32> = (0..8 * 77).map(|i| (i % 7) as f32 / 7.0).collect();
    let want = Simulator::new(&net).simulate_batch(&x);

    // Compiling under the alias lands on the detected concrete width.
    let concrete = lane_backend_name(detect_lane_words()).unwrap();
    let fabric = model
        .compile(&FabricOptions::new().backend(" Bitsliced-AUTO "))
        .unwrap();
    assert_eq!(fabric.backend_name(), concrete);
    let got = fabric.session().infer_batch(&x).unwrap();
    assert_eq!(got.logit_codes, want.logit_codes);

    // Saving records the concrete name — never the alias — and a load
    // pinned to the alias accepts the artifact it produced.
    let path = nfab("auto");
    fabric.save(&path).unwrap();
    let loaded = model
        .load_fabric(&FabricOptions::new().backend("bitsliced-auto"), &path)
        .unwrap();
    assert_eq!(loaded.backend_name(), concrete);
    assert_eq!(
        loaded.session().infer_batch(&x).unwrap().logit_codes,
        want.logit_codes
    );
}

#[test]
fn engine_env_override_selects_a_bit_exact_backend() {
    // The CI matrix leg sets NEURALUT_ENGINE=bitsliced-x4; this pins the
    // same path deterministically via the env injection hook.
    let net = random_network(98, 7, 2, &[5, 3], 2, 2, 4);
    let model = Model::from_network(net.clone());
    let x: Vec<f32> = (0..7 * 130).map(|i| (i % 23) as f32 / 23.0).collect();
    let want = Simulator::new(&net).simulate_batch(&x);
    for name in ["bitsliced-x4", "bitsliced-auto"] {
        let env = |key: &str| (key == "NEURALUT_ENGINE").then(|| name.to_string());
        let opts = FabricOptions::with_env(&env, None).unwrap();
        let fabric = model.compile(&opts).unwrap();
        assert!(fabric.backend_name().starts_with("bitsliced"), "{name}");
        let got = fabric.session().infer_batch(&x).unwrap();
        assert_eq!(got.logit_codes, want.logit_codes, "{name}");
        assert_eq!(got.predictions, want.predictions, "{name}");
    }
}
